//! Discrete-event simulator: executes a planned job queue against a
//! modelled GPU pool at the paper's scale (8×A100-40G / 8×A10-24G,
//! Qwen/LLaMa-class geometries) — the machinery behind the Figure 4/5/6/7
//! and §6 reproductions.
//!
//! The simulator re-derives the timeline independently of the planner's
//! predictions: jobs launch under the same [`Policy`] vocabulary as the
//! live [`crate::session::Session`] (FIFO head-of-line, priority
//! backfill, or strict priority with preemption), may carry **arrival
//! times** (skewed-arrival scenarios), durations come from the cost model
//! optionally perturbed by lognormal noise (robustness ablation — the
//! planner plans on clean estimates, reality jitters), and every
//! preemption-resume charges the cost model's `bucket_switch_cost` term —
//! the same penalty the live retarget planner weighs (as does every
//! mid-job bucket switch).
//!
//! It speaks the session's language: every run emits the same
//! [`Event`] stream a live session does (`JobStarted`, `AdapterFinished`
//! at cost-model phase boundaries, `Rebucketed`, `Preempted`,
//! `JobFinished`), and the per-job timeline in [`SimResult::jobs`] is
//! reconstructed *from that log* — so simulated and live traces can be
//! compared or rendered by the same consumers.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::LoraConfig;
use crate::costmodel::{CostModel, JobPhase, Pack, TrainBudget};
use crate::planner::{JobPlanner, PlannedJob};
use crate::search::rung_datasets;
use crate::session::{Event, Policy};
use crate::util::rng::Rng;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Lognormal sigma applied to each job duration (0 = deterministic).
    pub noise: f64,
    pub seed: u64,
    /// Queue dispatch policy (the session's vocabulary).
    pub policy: Policy,
    /// Elastic adapter-level admission: queued adapters join running
    /// packs at their completion boundaries (`AdapterAdmitted`), under
    /// the live session's gates — same policy order, same cross-`d`
    /// penalty-vs-wait formula. Default off (the pre-elastic timeline).
    pub elastic: bool,
    /// Boundary device retargeting: running packs grow onto freed devices
    /// (`DeviceRetarget`) when the modeled remaining-time saving beats
    /// `Calib::device_switch_cost`. Default off.
    pub grow_devices: bool,
    /// Boundary stage retargeting: running packs deepen their stage
    /// pipeline (`StageRetarget`) when the modeled utilization saving
    /// beats `Calib::stage_switch_cost`. Stages are workers on the job's
    /// own devices, so unlike `grow_devices` no pool capacity is taken.
    /// Default off.
    pub grow_stages: bool,
    /// Early-stopping tuner `(eta, rungs)` modeled by
    /// [`Simulator::run_asha`] — predicts the ASHA makespan win before a
    /// live `plora sweep --tuner asha` pays for it. `None` = full sweep.
    pub tuner: Option<(usize, usize)>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            noise: 0.0,
            seed: 42,
            policy: Policy::Fifo,
            elastic: false,
            grow_devices: false,
            grow_stages: false,
            tuner: None,
        }
    }
}

/// One simulated job execution.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: usize,
    pub d: usize,
    pub n_configs: usize,
    pub rank_sum: usize,
    pub start: f64,
    pub end: f64,
    pub devices: Vec<usize>,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Per-job timeline, reconstructed from the event log (first launch
    /// to final finish for preempted-and-resumed jobs).
    pub jobs: Vec<SimJob>,
    pub makespan: f64,
    /// Busy seconds per device.
    pub device_busy: Vec<f64>,
    /// Scheduler decision points (phase / arrival / preemption events
    /// advanced past).
    pub events: usize,
    /// The session-compatible event stream of the whole run.
    pub log: Vec<Event>,
}

impl SimResult {
    /// Pool utilization: busy device-seconds over `G × makespan`.
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.device_busy.iter().sum::<f64>() / (self.device_busy.len() as f64 * self.makespan)
    }

    /// Aggregate rank-unit throughput (the Fig. 5/7 metric).
    pub fn rank_throughput(&self) -> f64 {
        let work: usize = self.jobs.iter().map(|j| j.rank_sum).sum();
        work as f64 / self.makespan.max(1e-9)
    }

    /// Number of `Preempted` events in the log.
    pub fn preemptions(&self) -> usize {
        self.log.iter().filter(|e| matches!(e, Event::Preempted { .. })).count()
    }
}

/// One queued (or preempted-and-requeued) job awaiting devices.
struct Pend {
    qi: usize,
    seq: usize,
    prio: i32,
    arrive: f64,
    /// Remaining phases + partial progress of a preempted job.
    resume: Option<ResumeSim>,
}

struct ResumeSim {
    phases: Vec<JobPhase>,
    next: usize,
    /// Seconds left of phase `next` when the job was preempted.
    partial_left: f64,
    shape: (usize, usize, usize),
    factor: f64,
    /// Per-member remaining steps as of the interrupted phase's start.
    members: Vec<(LoraConfig, usize)>,
    /// Stage-pipeline depth at preemption (retargets survive the resume).
    stages: usize,
}

/// One job currently holding devices.
struct Run {
    qi: usize,
    seq: usize,
    prio: i32,
    devices: Vec<usize>,
    phases: Vec<JobPhase>,
    next: usize,
    phase_end: f64,
    shape: (usize, usize, usize),
    factor: f64,
    seg_start: f64,
    /// Start of the current busy-accounting window: equals `seg_start`
    /// until a device growth credits the old device set and restarts the
    /// window for the widened one (`seg_start` keeps the launch time, so
    /// `JobFinished.wall` still spans the whole segment).
    busy_start: f64,
    /// Per-member `(config, remaining steps)` — updated at boundaries;
    /// elastic admission appends joiners here and the phase plan is
    /// rebuilt from it.
    members: Vec<(LoraConfig, usize)>,
    /// Current stage-pipeline depth: phase durations are divided by the
    /// cost model's pipeline speedup at this depth and the executing
    /// bucket's slot count.
    stages: usize,
}

/// The simulator.
pub struct Simulator {
    pub cm: CostModel,
    pub budget: TrainBudget,
    pub gpus: usize,
}

impl Simulator {
    pub fn new(cm: CostModel, gpus: usize) -> Simulator {
        Simulator { cm, budget: TrainBudget::default(), gpus }
    }

    /// Execute a job queue on the modelled pool under `opts.policy` with
    /// all priorities 0 and simultaneous arrival.
    pub fn run_queue(&self, queue: &[PlannedJob], opts: &SimOptions) -> SimResult {
        self.run_queue_prio(queue, &[], opts)
    }

    /// Execute with explicit per-job priorities (`prios[i]` belongs to
    /// `queue[i]`; missing entries are 0), simultaneous arrival.
    pub fn run_queue_prio(
        &self,
        queue: &[PlannedJob],
        prios: &[i32],
        opts: &SimOptions,
    ) -> SimResult {
        self.run_queue_arrivals(queue, prios, &[], opts)
    }

    /// The full policy path: per-job priorities and arrival times
    /// (`arrivals[i]` seconds; missing entries arrive at 0). A job is
    /// invisible to the dispatcher before its arrival — the skewed-arrival
    /// scenarios where priority and preemption earn their keep.
    pub fn run_queue_arrivals(
        &self,
        queue: &[PlannedJob],
        prios: &[i32],
        arrivals: &[f64],
        opts: &SimOptions,
    ) -> SimResult {
        let mut rng = Rng::new(opts.seed);
        let switch_cost = self.cm.calib.bucket_switch_cost;
        let dev_switch = self.cm.calib.device_switch_cost;
        let stage_switch = self.cm.calib.stage_switch_cost;
        let layer_cap = self.cm.geom.n_layers.max(1);
        // Pipeline speedup at depth `s` for a bucket of `n` slots
        // (microbatch = slot — the driver's deterministic schedule).
        let spd = |s: usize, n: usize| self.cm.pipeline_speedup(s.min(layer_cap), n.max(1));
        // Per-queue-entry remaining configs: elastic admission drains a
        // queued job's pack before (or instead of) its launch.
        let mut packs: Vec<Vec<LoraConfig>> =
            queue.iter().map(|j| j.pack.configs.clone()).collect();
        // Realized (n, rank-sum) per job id — launch-time membership plus
        // admitted joiners; the timeline reconstruction reads it.
        let mut stats: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
        let mut free: Vec<usize> = (0..self.gpus).collect();
        let mut pending: Vec<Pend> = queue
            .iter()
            .enumerate()
            .map(|(i, _)| Pend {
                qi: i,
                seq: i,
                prio: prios.get(i).copied().unwrap_or(0),
                arrive: arrivals.get(i).copied().unwrap_or(0.0),
                resume: None,
            })
            .collect();
        let mut running: Vec<Run> = vec![];
        let mut now = 0.0f64;
        let mut log: Vec<Event> = vec![];
        let mut busy = vec![0.0f64; self.gpus];
        let mut events = 0usize;

        // Next launchable pending index under the policy, among arrived
        // jobs. FIFO and PreemptLowest block on their head (submission /
        // priority order); Priority backfills past a too-big head.
        let pick = |pending: &[Pend], now: f64, avail: usize| -> Option<usize> {
            let arrived = |p: &Pend| p.arrive <= now + 1e-12;
            match opts.policy {
                Policy::Fifo => {
                    let (idx, head) = pending
                        .iter()
                        .enumerate()
                        .filter(|(_, p)| arrived(p))
                        .min_by_key(|(_, p)| p.seq)?;
                    (queue[head.qi].d <= avail).then_some(idx)
                }
                Policy::Priority => {
                    let mut order: Vec<usize> = (0..pending.len())
                        .filter(|&i| arrived(&pending[i]))
                        .collect();
                    order.sort_by_key(|&i| (std::cmp::Reverse(pending[i].prio), pending[i].seq));
                    order.into_iter().find(|&i| queue[pending[i].qi].d <= avail)
                }
                Policy::PreemptLowest => {
                    // Strict priority: never backfill past a starved
                    // higher-priority job (its devices are being vacated —
                    // backfilling would re-occupy them and livelock).
                    let (idx, head) = pending
                        .iter()
                        .enumerate()
                        .filter(|(_, p)| arrived(p))
                        .min_by_key(|(_, p)| (std::cmp::Reverse(p.prio), p.seq))?;
                    (queue[head.qi].d <= avail).then_some(idx)
                }
            }
        };

        while !pending.is_empty() || !running.is_empty() {
            // Launch while the policy grants devices.
            while let Some(idx) = pick(&pending, now, free.len()) {
                let p = pending.remove(idx);
                let job = &queue[p.qi];
                let devices: Vec<usize> = free.drain(..job.d).collect();
                let (phases, next, first_dur, shape, factor, members, stages) = match p.resume {
                    Some(r) => {
                        // Resuming pays the restore side of the switch.
                        (
                            r.phases,
                            r.next,
                            r.partial_left + switch_cost,
                            r.shape,
                            r.factor,
                            r.members,
                            r.stages,
                        )
                    }
                    None => {
                        // The pack as it stands now — elastic admission
                        // may have absorbed some (or most) of it already.
                        let pk = Pack::new(packs[p.qi].clone());
                        let phases = self.cm.job_phases(&pk, job.d, job.mode, &self.budget);
                        // Noise perturbs the whole job's duration once;
                        // phases stretch uniformly so boundary order is
                        // preserved.
                        let factor = if opts.noise > 0.0 {
                            (opts.noise * rng.normal()).exp()
                        } else {
                            1.0
                        };
                        let shape = (pk.n(), pk.r_pad(), pk.bs_pad());
                        let stages = job.stages().min(layer_cap);
                        let d0 = phases
                            .first()
                            .map(|p| p.dur * factor / spd(stages, shape.0))
                            .unwrap_or(0.0);
                        let members: Vec<(LoraConfig, usize)> = pk
                            .configs
                            .iter()
                            .map(|c| (c.clone(), self.budget.steps(c.batch)))
                            .collect();
                        (phases, 0usize, d0, shape, factor, members, stages)
                    }
                };
                stats
                    .entry(job.id)
                    .or_insert((members.len(), members.iter().map(|m| m.0.rank).sum()));
                log.push(Event::JobStarted {
                    job: job.id,
                    n_adapters: members.len(),
                    devices: devices.clone(),
                    at: now,
                });
                let first_dur = if next >= phases.len() { 0.0 } else { first_dur };
                running.push(Run {
                    qi: p.qi,
                    seq: p.seq,
                    prio: p.prio,
                    devices,
                    phases,
                    next,
                    phase_end: now + first_dur,
                    shape,
                    factor,
                    seg_start: now,
                    busy_start: now,
                    members,
                    stages,
                });
            }

            // Preemption: a starved higher-priority job evicts strictly
            // lower-priority running jobs — but only when evicting enough
            // of them actually frees what it needs.
            if opts.policy == Policy::PreemptLowest {
                let starved = pending
                    .iter()
                    .filter(|p| p.arrive <= now + 1e-12)
                    .min_by_key(|p| (std::cmp::Reverse(p.prio), p.seq))
                    .map(|p| (p.prio, queue[p.qi].d));
                if let Some((top_prio, need)) = starved {
                    let takeable: usize = running
                        .iter()
                        .filter(|r| r.prio < top_prio)
                        .map(|r| r.devices.len())
                        .sum();
                    if need > free.len() && free.len() + takeable >= need {
                        // Evict lowest-priority victims until it fits.
                        while free.len() < need {
                            let (vi, _) = running
                                .iter()
                                .enumerate()
                                .filter(|(_, r)| r.prio < top_prio)
                                .min_by_key(|(_, r)| (r.prio, std::cmp::Reverse(r.seq)))
                                .expect("takeable victims verified above");
                            events += 1;
                            let r = running.swap_remove(vi);
                            let job = &queue[r.qi];
                            let width = r.devices.len();
                            for &dev in &r.devices {
                                busy[dev] += now - r.busy_start;
                            }
                            free.extend(r.devices);
                            free.sort_unstable();
                            // The member ledger (boundary-updated, with
                            // admitted joiners) is the source of truth
                            // for who is still training.
                            let remaining: Vec<usize> = r
                                .members
                                .iter()
                                .filter(|m| m.1 > 0)
                                .map(|m| m.0.id)
                                .collect();
                            log.push(Event::Preempted {
                                job: job.id,
                                adapters: remaining,
                                at: now,
                            });
                            // A grown run resumes at its *original* width
                            // (job.d is what the relaunch will drain):
                            // rebuild the remaining plan at that width,
                            // carrying the interrupted phase's remaining
                            // fraction of work.
                            let resume = if width != job.d && r.next < r.phases.len() {
                                let cur = r.phases[r.next].dur * r.factor;
                                let frac = if cur > 0.0 {
                                    ((r.phase_end - now) / cur).clamp(0.0, 1.0)
                                } else {
                                    0.0
                                };
                                let phases = self.cm.phases_from_remaining(
                                    &r.members,
                                    job.d,
                                    job.mode,
                                );
                                let partial_left = phases
                                    .first()
                                    .map(|p| frac * p.dur * r.factor / spd(r.stages, r.shape.0))
                                    .unwrap_or(0.0);
                                ResumeSim {
                                    partial_left,
                                    phases,
                                    next: 0,
                                    shape: r.shape,
                                    factor: r.factor,
                                    members: r.members,
                                    stages: r.stages,
                                }
                            } else {
                                ResumeSim {
                                    partial_left: (r.phase_end - now).max(0.0),
                                    phases: r.phases,
                                    next: r.next,
                                    shape: r.shape,
                                    factor: r.factor,
                                    members: r.members,
                                    stages: r.stages,
                                }
                            };
                            pending.push(Pend {
                                qi: r.qi,
                                seq: r.seq,
                                prio: r.prio,
                                arrive: now,
                                resume: Some(resume),
                            });
                        }
                        continue; // re-run launches at the same instant
                    }
                }
            }

            // Next event: the earliest phase boundary or job arrival.
            let next_phase = running.iter().map(|r| r.phase_end).fold(f64::INFINITY, f64::min);
            let next_arrival = pending
                .iter()
                .map(|p| p.arrive)
                .filter(|&a| a > now + 1e-12)
                .fold(f64::INFINITY, f64::min);
            if running.is_empty() {
                if pending.is_empty() {
                    break;
                }
                if next_arrival.is_finite() {
                    events += 1;
                    now = next_arrival;
                    continue;
                }
                // Arrived head larger than the whole pool: impossible.
                let hd = pending
                    .iter()
                    .min_by_key(|p| (std::cmp::Reverse(p.prio), p.seq))
                    .unwrap();
                let j = &queue[hd.qi];
                panic!("sim: job {} wants {} devices, pool has {}", j.id, j.d, self.gpus);
            }
            if next_arrival < next_phase {
                events += 1;
                now = next_arrival;
                continue;
            }

            // Advance to the earliest phase boundary.
            events += 1;
            let (idx, _) = running
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.phase_end.total_cmp(&b.1.phase_end))
                .unwrap();
            now = running[idx].phase_end.max(now);
            let mut retired: Vec<usize> = vec![];
            let finished_job = {
                let r = &mut running[idx];
                let job = &queue[r.qi];
                if r.next < r.phases.len() {
                    let p = r.phases[r.next].clone();
                    for &id in &p.finished {
                        log.push(Event::AdapterFinished {
                            job: job.id,
                            adapter: id,
                            task: String::new(),
                            steps: 0,
                            eval_loss: f32::NAN,
                            eval_acc: f32::NAN,
                            at: now,
                        });
                    }
                    // Per-member progress: the executed phase advanced
                    // every then-alive member by its step count.
                    for m in r.members.iter_mut() {
                        m.1 -= p.steps.min(m.1);
                    }
                    let mut switch_pay = 0.0;
                    if p.survivors.0 > 0 && p.survivors != r.shape {
                        log.push(Event::Rebucketed {
                            job: job.id,
                            from: r.shape,
                            to: p.survivors,
                            survivors: vec![],
                            at: now,
                        });
                        r.shape = p.survivors;
                        switch_pay = switch_cost;
                    }
                    r.next += 1;
                    let mut rebuilt = false;
                    if r.next < r.phases.len() {
                        // Elastic boundary: queued adapters may join the
                        // surviving pack — same policy order, priority
                        // ceiling and cross-`d` penalty-vs-wait gate as
                        // the live session's `offer_joiners`.
                        if opts.elastic {
                            let host_d = r.devices.len();
                            let mut alive: Vec<LoraConfig> = r
                                .members
                                .iter()
                                .filter(|m| m.1 > 0)
                                .map(|m| m.0.clone())
                                .collect();
                            let host_remaining =
                                r.members.iter().map(|m| m.1).max().unwrap_or(0);
                            let mut order: Vec<usize> = (0..pending.len())
                                .filter(|&i| {
                                    pending[i].arrive <= now + 1e-12
                                        && pending[i].resume.is_none()
                                })
                                .collect();
                            match opts.policy {
                                Policy::Fifo => order.sort_by_key(|&i| pending[i].seq),
                                _ => order.sort_by_key(|&i| {
                                    (std::cmp::Reverse(pending[i].prio), pending[i].seq)
                                }),
                            }
                            for i in order {
                                let pq = &pending[i];
                                let qj = &queue[pq.qi];
                                if pq.prio > r.prio || qj.mode != job.mode {
                                    continue;
                                }
                                let d_ok = qj.d == host_d || {
                                    // The live session's gate, verbatim
                                    // (CostModel::cross_d_admit).
                                    let own = {
                                        let pk = Pack::new(packs[pq.qi].clone());
                                        (pk.n(), pk.r_pad(), pk.bs_pad())
                                    };
                                    let steps = packs[pq.qi]
                                        .iter()
                                        .map(|c| self.budget.steps(c.batch))
                                        .max()
                                        .unwrap_or(0);
                                    self.cm.cross_d_admit(
                                        r.shape,
                                        host_d,
                                        host_remaining,
                                        own,
                                        qj.d,
                                        steps,
                                        qj.mode,
                                        dev_switch,
                                    )
                                };
                                if !d_ok {
                                    continue;
                                }
                                let qi = pq.qi;
                                let mut j = 0usize;
                                while j < packs[qi].len() {
                                    let cand = packs[qi][j].clone();
                                    let mut trial = alive.clone();
                                    trial.push(cand.clone());
                                    if !self.cm.fits(&Pack::new(trial), host_d) {
                                        j += 1;
                                        continue;
                                    }
                                    packs[qi].remove(j);
                                    log.push(Event::AdapterAdmitted {
                                        job: job.id,
                                        adapter: cand.id,
                                        task: cand.task.clone(),
                                        from_job: qj.id,
                                        at: now,
                                    });
                                    let st = stats.entry(job.id).or_insert((0, 0));
                                    st.0 += 1;
                                    st.1 += cand.rank;
                                    let steps_j = self.budget.steps(cand.batch);
                                    alive.push(cand.clone());
                                    r.members.push((cand, steps_j));
                                    rebuilt = true;
                                }
                            }
                            // Retire queue entries fully absorbed: they
                            // never launch; their adapters report under
                            // the host job.
                            let mut k = 0usize;
                            while k < pending.len() {
                                if pending[k].resume.is_none()
                                    && packs[pending[k].qi].is_empty()
                                {
                                    retired.push(queue[pending[k].qi].id);
                                    pending.remove(k);
                                } else {
                                    k += 1;
                                }
                            }
                        }
                        // Device retarget: grow onto freed devices when
                        // the modeled remaining-time saving beats the
                        // device-switch cost — the session's gate,
                        // including its "queue first" rule: an *arrived*
                        // pending job has first claim on free devices.
                        let queue_idle =
                            pending.iter().all(|p| p.arrive > now + 1e-12);
                        if opts.grow_devices && queue_idle && !free.is_empty() {
                            let d = r.devices.len();
                            // Same cap as the session's offer_devices:
                            // at most double, never past the executing
                            // shape's slot count.
                            let extra =
                                free.len().min(d).min(r.shape.0.saturating_sub(d));
                            if extra > 0 {
                                let to = d + extra;
                                // The live session's gate: the *next
                                // phase's* saving (shape-charged step
                                // times, realized via the noise factor)
                                // must beat the device-switch cost.
                                let steps = r.phases[r.next].steps as f64;
                                let t_cur =
                                    self.cm.bucket_step_time(r.shape, d, job.mode);
                                let t_new =
                                    self.cm.bucket_step_time(r.shape, to, job.mode);
                                let saving = steps * (t_cur - t_new) * r.factor;
                                if saving > dev_switch {
                                    for &dev in &r.devices {
                                        busy[dev] += now - r.busy_start;
                                    }
                                    r.busy_start = now;
                                    let new_devs: Vec<usize> = free.drain(..extra).collect();
                                    r.devices.extend(new_devs);
                                    log.push(Event::DeviceRetarget {
                                        job: job.id,
                                        from: d,
                                        to,
                                        at: now,
                                    });
                                    switch_pay += dev_switch;
                                    rebuilt = true;
                                }
                            }
                        }
                        // Stage retarget: deepen the pipeline when the
                        // next phase's modeled saving beats the
                        // stage-switch cost — the session's offer_stages
                        // gate. Stages are workers on the job's own
                        // devices, so free devices and the queue are
                        // irrelevant to the decision.
                        if opts.grow_stages && r.stages < layer_cap {
                            let from = r.stages;
                            let to = (from * 2).min(layer_cap);
                            let d = r.devices.len();
                            let steps = r.phases[r.next].steps as f64;
                            let t_cur = self.cm.bucket_step_time_ds(r.shape, d, from, job.mode);
                            let t_new = self.cm.bucket_step_time_ds(r.shape, d, to, job.mode);
                            let saving = steps * (t_cur - t_new) * r.factor;
                            if to > from && saving > stage_switch {
                                log.push(Event::StageRetarget {
                                    job: job.id,
                                    from,
                                    to,
                                    at: now,
                                });
                                switch_pay += stage_switch;
                                r.stages = to;
                            }
                        }
                    }
                    if rebuilt {
                        let alive: Vec<(LoraConfig, usize)> =
                            r.members.iter().filter(|m| m.1 > 0).cloned().collect();
                        let pk = Pack::new(alive.iter().map(|m| m.0.clone()).collect());
                        let new_shape = (pk.n(), pk.r_pad(), pk.bs_pad());
                        if new_shape.0 > 0 && new_shape != r.shape {
                            log.push(Event::Rebucketed {
                                job: job.id,
                                from: r.shape,
                                to: new_shape,
                                survivors: vec![],
                                at: now,
                            });
                            r.shape = new_shape;
                            switch_pay += switch_cost;
                        }
                        r.phases =
                            self.cm.phases_from_remaining(&alive, r.devices.len(), job.mode);
                        r.next = 0;
                    }
                    if r.next < r.phases.len() {
                        let dur = r.phases[r.next].dur * r.factor / spd(r.stages, r.shape.0);
                        r.phase_end = now + switch_pay + dur;
                        false
                    } else {
                        true
                    }
                } else {
                    true
                }
            };
            for job in retired {
                log.push(Event::JobFinished { job, adapters: 0, wall: 0.0, at: now });
            }
            if finished_job {
                let r = running.swap_remove(idx);
                let job = &queue[r.qi];
                for &dev in &r.devices {
                    busy[dev] += now - r.busy_start;
                }
                log.push(Event::JobFinished {
                    job: job.id,
                    adapters: r.members.len(),
                    wall: now - r.seg_start,
                    at: now,
                });
                free.extend(r.devices);
                free.sort_unstable();
            }
        }

        // Order the log by timestamp so it reads like a live session's
        // stream; the stable sort keeps same-instant events in emission
        // order.
        log.sort_by(|a, b| a.at().total_cmp(&b.at()));

        // The timeline is read back off the event log (same stream a live
        // session emits), joined with the queue's static job facts. A
        // preempted job's SimJob spans first launch to final finish.
        let by_id: BTreeMap<usize, &PlannedJob> = queue.iter().map(|j| (j.id, j)).collect();
        let mut jobs: Vec<SimJob> = vec![];
        let mut open: BTreeMap<usize, usize> = BTreeMap::new(); // job id -> index
        for ev in &log {
            match ev {
                Event::JobStarted { job, devices, at, .. } => {
                    if let Some(&i) = open.get(job) {
                        jobs[i].devices = devices.clone();
                        continue;
                    }
                    let pj = by_id[job];
                    // Realized membership (launch set + admitted joiners)
                    // when the run tracked one; queue facts otherwise.
                    let (n_c, r_s) = stats
                        .get(job)
                        .copied()
                        .unwrap_or((pj.pack.n(), pj.pack.rank_sum()));
                    open.insert(*job, jobs.len());
                    jobs.push(SimJob {
                        id: *job,
                        d: pj.d,
                        n_configs: n_c,
                        rank_sum: r_s,
                        start: *at,
                        end: *at,
                        devices: devices.clone(),
                    });
                }
                Event::JobFinished { job, at, .. } => {
                    if let Some(&i) = open.get(job) {
                        jobs[i].end = *at;
                    }
                }
                _ => {}
            }
        }

        let makespan = jobs.iter().map(|j| j.end).fold(0.0, f64::max);
        SimResult { jobs, makespan, device_busy: busy, events, log }
    }

    /// Structural ASHA makespan model (`plora sim --tuner asha`,
    /// `opts.tuner = (eta, rungs)`): rung `k` keeps the first
    /// `max(1, n/eta)` trials per task — the sim cannot know quality, and
    /// the makespan depends only on the survivor *count* — each paying
    /// only the incremental steps from the previous rung's dataset, with
    /// each rung planned and simulated as its own queue on the full pool.
    ///
    /// Rungs execute synchronously here (rung `k+1` starts when rung
    /// `k`'s last job finishes); the live tuner promotes eagerly at
    /// adapter boundaries, so this is a conservative (upper) estimate of
    /// the ASHA makespan. Per-rung sub-logs are not carried over — the
    /// returned log holds one [`Event::RungDecision`] per task per
    /// non-final rung, timestamped at the rung boundary.
    pub fn run_asha(&self, configs: &[LoraConfig], opts: &SimOptions) -> Result<SimResult> {
        let (eta, rungs) = opts.tuner.unwrap_or((2, 3));
        // Clamp once for both the ladder and the survivor counts below —
        // `--eta 0` must not divide by zero, `--eta 1` must still halve.
        let eta = eta.max(2);
        let ladder = rung_datasets(self.budget.dataset, eta, rungs.max(1));
        let mut groups: BTreeMap<&str, Vec<&LoraConfig>> = BTreeMap::new();
        for c in configs {
            groups.entry(c.task.as_str()).or_default().push(c);
        }
        let mut counts: BTreeMap<&str, usize> =
            groups.iter().map(|(&t, g)| (t, g.len())).collect();
        let mut jobs: Vec<SimJob> = vec![];
        let mut busy = vec![0.0f64; self.gpus];
        let mut events = 0usize;
        let mut log: Vec<Event> = vec![];
        let mut offset = 0.0f64;
        let mut prev_dataset = 0usize;
        for (k, &dk) in ladder.iter().enumerate() {
            let rung_cfgs: Vec<LoraConfig> = groups
                .iter()
                .flat_map(|(t, g)| g.iter().take(counts[t]).map(|&c| c.clone()))
                .collect();
            let inc = TrainBudget { dataset: dk - prev_dataset, epochs: self.budget.epochs };
            let mut planner = JobPlanner::new(self.cm.clone(), self.gpus);
            planner.budget = inc;
            let plan = planner.plan(&rung_cfgs)?;
            let queue: Vec<PlannedJob> = plan.jobs.iter().map(|j| j.job.clone()).collect();
            let sub = Simulator { cm: self.cm.clone(), budget: inc, gpus: self.gpus };
            let res = sub.run_queue(&queue, &SimOptions { tuner: None, ..opts.clone() });
            for mut j in res.jobs {
                j.start += offset;
                j.end += offset;
                jobs.push(j);
            }
            for (b, add) in busy.iter_mut().zip(&res.device_busy) {
                *b += add;
            }
            events += res.events;
            offset += res.makespan;
            prev_dataset = dk;
            if k + 1 < ladder.len() {
                for (&t, n) in counts.iter_mut() {
                    let keep = (*n / eta).max(1);
                    let g = &groups[t];
                    log.push(Event::RungDecision {
                        rung: k,
                        task: t.to_string(),
                        survivors: g.iter().take(keep).map(|c| c.id).collect(),
                        demoted: g.iter().take(*n).skip(keep).map(|c| c.id).collect(),
                        at: offset,
                    });
                    *n = keep;
                }
            }
        }
        Ok(SimResult { jobs, makespan: offset, device_busy: busy, events, log })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::{LoraConfig, SearchSpace};
    use crate::costmodel::{ExecMode, Pack};
    use crate::planner::{min_gpu_plan, JobPlanner};

    fn sim(model: &str) -> Simulator {
        Simulator::new(CostModel::new(geom(model).unwrap(), &A100_40G), 8)
    }

    #[test]
    fn sim_agrees_with_planner_prediction_when_deterministic() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        let rel = (res.makespan - plan.makespan).abs() / plan.makespan;
        assert!(
            rel < 0.05,
            "sim {:.0}s vs plan {:.0}s ({:.1}% off)",
            res.makespan,
            plan.makespan,
            rel * 100.0
        );
    }

    #[test]
    fn devices_never_oversubscribed() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        // At any event point, active jobs' devices must be disjoint.
        let points: Vec<f64> = res.jobs.iter().map(|j| j.start + 1e-6).collect();
        for &t in &points {
            let mut used = std::collections::BTreeSet::new();
            for j in res.jobs.iter().filter(|j| j.start <= t && t < j.end) {
                for &d in &j.devices {
                    assert!(used.insert(d), "device {d} double-booked at t={t}");
                }
            }
            assert!(used.len() <= 8);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_feasibility() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let clean = s.run_queue(&queue, &SimOptions::default());
        let noisy = s.run_queue(&queue, &SimOptions { noise: 0.2, seed: 7, ..Default::default() });
        assert!(noisy.makespan != clean.makespan);
        assert!((noisy.makespan / clean.makespan - 1.0).abs() < 0.5);
        assert_eq!(noisy.jobs.len(), clean.jobs.len());
    }

    /// The ASHA model predicts a strict makespan win over the full sweep
    /// of the same grid, and records one rung decision per task per
    /// non-final rung with survivor counts shrunk by eta.
    #[test]
    fn asha_sim_predicts_makespan_win() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let full = s.run_queue(&queue, &SimOptions::default());
        let asha = s
            .run_asha(&grid, &SimOptions { tuner: Some((2, 3)), ..Default::default() })
            .unwrap();
        assert!(
            asha.makespan < full.makespan,
            "asha {:.0}s !< full {:.0}s",
            asha.makespan,
            full.makespan
        );
        let decisions: Vec<_> = asha
            .log
            .iter()
            .filter_map(|e| match e {
                Event::RungDecision { rung, survivors, demoted, .. } => {
                    Some((*rung, survivors.len(), demoted.len()))
                }
                _ => None,
            })
            .collect();
        // 120-trial grid, eta=2: 120 -> 60 -> 30 over 3 rungs.
        assert_eq!(decisions, vec![(0, 60, 60), (1, 30, 30)]);
    }

    #[test]
    fn utilization_and_throughput_positive() {
        let s = sim("qwen2.5-3b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        assert!(res.utilization() > 0.5 && res.utilization() <= 1.0);
        assert!(res.rank_throughput() > 0.0);
    }

    /// A mixed-batch pack produces the session event vocabulary: started,
    /// adapter-finished at phase boundaries, re-bucketed, finished — and
    /// the job timeline in `jobs` is exactly what the log says.
    #[test]
    fn event_log_carries_phases_and_rebuckets() {
        let s = sim("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let queue = vec![PlannedJob {
            id: 0,
            pack: Pack::new(vec![cfg(0, 1), cfg(1, 4)]),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        }];
        let res = s.run_queue(&queue, &SimOptions::default());
        let kinds: Vec<&str> = res
            .log
            .iter()
            .map(|e| match e {
                Event::JobStarted { .. } => "started",
                Event::AdapterFinished { .. } => "adapter",
                Event::AdapterAdmitted { .. } => "admitted",
                Event::Rebucketed { .. } => "rebucket",
                Event::Preempted { .. } => "preempted",
                Event::DeviceRetarget { .. } => "retarget",
                Event::StageRetarget { .. } => "stage",
                Event::JobFinished { .. } => "finished",
                Event::JobFailed { .. } => "failed",
                Event::TrialPromoted { .. } => "promoted",
                Event::RungDecision { .. } => "rung",
                Event::CalibUpdated { .. } => "calib",
            })
            .collect();
        assert_eq!(kinds, vec!["started", "adapter", "rebucket", "adapter", "finished"]);
        // bs4 (fewer steps) leaves first; survivors shrink to (1, 16, 1).
        let Some(Event::Rebucketed { from, to, .. }) =
            res.log.iter().find(|e| matches!(e, Event::Rebucketed { .. }))
        else {
            panic!("no rebucket event");
        };
        assert_eq!(*from, (2, 16, 4));
        assert_eq!(*to, (1, 16, 1));
        // Timeline rebuilt from the log matches the cost model exactly.
        assert_eq!(res.jobs.len(), 1);
        let want = s.cm.job_time(&queue[0].pack, 1, ExecMode::Packed, &s.budget);
        assert!((res.jobs[0].end - res.jobs[0].start - want).abs() < 1e-9);
        // Event timestamps are monotone.
        for w in res.log.windows(2) {
            assert!(w[0].at() <= w[1].at() + 1e-12);
        }
    }

    /// Elastic adapter-level admission: a queued single-adapter job joins
    /// the running mixed pack at its first completion boundary
    /// (`AdapterAdmitted`), its queue entry retires with a zero-adapter
    /// `JobFinished`, and the makespan strictly beats the non-elastic
    /// run of the same queue on the same single device.
    #[test]
    fn elastic_admission_joins_running_pack_and_shrinks_makespan() {
        let mut s = sim("qwen2.5-7b");
        s.gpus = 1;
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        // Job 0 holds the device; its bs4 member leaves at the first
        // boundary, freeing room for queued job 1's adapter.
        let queue = vec![
            PlannedJob {
                id: 0,
                pack: Pack::new(vec![cfg(0, 1), cfg(1, 4)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            PlannedJob {
                id: 1,
                pack: Pack::new(vec![cfg(2, 4)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
        ];
        let plain = s.run_queue(&queue, &SimOptions::default());
        let elastic = s.run_queue(
            &queue,
            &SimOptions { elastic: true, ..SimOptions::default() },
        );
        let admissions = elastic
            .log
            .iter()
            .filter(|e| matches!(e, Event::AdapterAdmitted { .. }))
            .count();
        assert_eq!(admissions, 1, "the queued adapter must join at the boundary");
        assert!(elastic
            .log
            .iter()
            .any(|e| matches!(e, Event::JobFinished { job: 1, adapters: 0, .. })));
        assert!(
            elastic.makespan < plain.makespan,
            "elastic {:.1}s !< plain {:.1}s",
            elastic.makespan,
            plain.makespan
        );
        // The host job's realized membership counts the joiner.
        let host = elastic.jobs.iter().find(|j| j.id == 0).unwrap();
        assert_eq!(host.n_configs, 3);
        assert_eq!(host.rank_sum, 48);
        // The absorbed job never launched.
        assert!(elastic.jobs.iter().all(|j| j.id != 1));
    }

    /// Boundary device growth: with a calibrated dp fit showing real
    /// parallel benefit and a free device, the surviving pack grows
    /// (`DeviceRetarget`) and finishes earlier; a prohibitive
    /// device-switch cost pins it at d=1.
    #[test]
    fn grow_devices_retargets_when_saving_beats_switch_cost() {
        let mut s = sim("qwen2.5-7b");
        s.gpus = 2;
        // Perfectly parallel measured fit: t_row = b/d.
        s.cm.calib.dp_fit = Some((0.0, 1e-3));
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let queue = vec![PlannedJob {
            id: 0,
            pack: Pack::new(vec![cfg(0, 1), cfg(1, 1), cfg(2, 4)]),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        }];
        let plain = s.run_queue(&queue, &SimOptions::default());
        let grown = s.run_queue(
            &queue,
            &SimOptions { grow_devices: true, ..SimOptions::default() },
        );
        let retargets = grown
            .log
            .iter()
            .filter(|e| matches!(e, Event::DeviceRetarget { .. }))
            .count();
        assert_eq!(retargets, 1, "the pack must grow onto the free device");
        assert!(
            grown.makespan < plain.makespan,
            "grown {:.1}s !< plain {:.1}s",
            grown.makespan,
            plain.makespan
        );
        // A prohibitive switch cost pins the pack at its launch width.
        s.cm.calib.device_switch_cost = f64::MAX;
        let pinned = s.run_queue(
            &queue,
            &SimOptions { grow_devices: true, ..SimOptions::default() },
        );
        assert!(pinned
            .log
            .iter()
            .all(|e| !matches!(e, Event::DeviceRetarget { .. })));
    }

    /// Stage pipelining in the sim: a planned depth divides each phase's
    /// duration by the cost model's pipeline speedup at the executing
    /// bucket's slot count, so the pipelined run lands exactly on the
    /// modeled timeline — and strictly beats depth 1.
    #[test]
    fn planned_stage_depth_matches_modeled_pipeline_speedup() {
        let s = sim("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let pack = Pack::new(vec![cfg(0, 1), cfg(1, 1), cfg(2, 1), cfg(3, 4)]);
        let queue_at = |st: usize| {
            vec![PlannedJob { id: 0, pack: pack.clone(), d: 1, s: st, mode: ExecMode::Packed }]
        };
        let base = s.run_queue(&queue_at(0), &SimOptions::default());
        let piped = s.run_queue(&queue_at(2), &SimOptions::default());
        assert!(
            piped.makespan < base.makespan,
            "s=2 {:.1}s !< s=1 {:.1}s",
            piped.makespan,
            base.makespan
        );
        // Exact timeline: phase 1 runs at the launch bucket (4 slots),
        // phase 2 at the survivor bucket (3 slots), one bucket switch in
        // between — each phase divided by its own pipeline speedup.
        let ph = s.cm.job_phases(&pack, 1, ExecMode::Packed, &s.budget);
        assert_eq!(ph.len(), 2);
        let want = ph[0].dur / s.cm.pipeline_speedup(2, 4)
            + s.cm.calib.bucket_switch_cost
            + ph[1].dur / s.cm.pipeline_speedup(2, 3);
        assert!(
            (piped.makespan - want).abs() < 1e-9,
            "piped {:.6}s vs modeled {:.6}s",
            piped.makespan,
            want
        );
    }

    /// Boundary stage growth: a depth-1 run deepens at its first phase
    /// boundary (`StageRetarget`) when the modeled saving beats the
    /// stage-switch cost, and finishes earlier; a prohibitive cost pins
    /// the depth.
    #[test]
    fn grow_stages_retargets_when_saving_beats_switch_cost() {
        let mut s = sim("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let queue = vec![PlannedJob {
            id: 0,
            pack: Pack::new(vec![cfg(0, 1), cfg(1, 1), cfg(2, 1), cfg(3, 4)]),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        }];
        let plain = s.run_queue(&queue, &SimOptions::default());
        let grown =
            s.run_queue(&queue, &SimOptions { grow_stages: true, ..SimOptions::default() });
        let retargets = grown
            .log
            .iter()
            .filter(|e| matches!(e, Event::StageRetarget { .. }))
            .count();
        assert_eq!(retargets, 1, "the pack must deepen at the boundary");
        assert!(
            grown.makespan < plain.makespan,
            "grown {:.1}s !< plain {:.1}s",
            grown.makespan,
            plain.makespan
        );
        // A prohibitive stage-switch cost pins the pipeline at depth 1.
        s.cm.calib.stage_switch_cost = f64::MAX;
        let pinned =
            s.run_queue(&queue, &SimOptions { grow_stages: true, ..SimOptions::default() });
        assert!(pinned.log.iter().all(|e| !matches!(e, Event::StageRetarget { .. })));
        assert!((pinned.makespan - plain.makespan).abs() < 1e-9);
    }

    /// The policy path on a skewed arrival: a high-priority job arriving
    /// mid-run evicts both lower-priority running jobs under
    /// `PreemptLowest` (two `Preempted` events, resumes charged one
    /// `bucket_switch_cost` each); under FIFO it simply waits. Work is
    /// conserved either way.
    #[test]
    fn preempt_lowest_evicts_on_late_high_priority_arrival() {
        let mut s = sim("qwen2.5-7b");
        s.gpus = 2;
        s.cm.calib.bucket_switch_cost = 5.0;
        let cfg = |id: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: 1,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let job = |id: usize, c0: usize, d: usize| PlannedJob {
            id,
            pack: Pack::new(vec![cfg(c0)]),
            d,
            s: 0,
            mode: ExecMode::Packed,
        };
        // A and B run on one device each; C (d=2, high priority) arrives
        // mid-run and needs the whole pool.
        let queue = vec![job(0, 0, 1), job(1, 1, 1), job(2, 2, 2)];
        let t_solo = s.cm.job_time(&queue[0].pack, 1, ExecMode::Packed, &s.budget);
        let t_c = s.cm.job_time(&queue[2].pack, 2, ExecMode::Packed, &s.budget);
        let arrive = t_solo * 0.5;
        let opts = |policy| SimOptions { policy, ..Default::default() };

        let fifo = s.run_queue_arrivals(
            &queue,
            &[1, 0, 3],
            &[0.0, 0.0, arrive],
            &opts(Policy::Fifo),
        );
        assert_eq!(fifo.preemptions(), 0);
        let cf = fifo.jobs.iter().find(|j| j.id == 2).unwrap();
        assert!((cf.start - t_solo).abs() < 1e-9, "FIFO: C waits for both to finish");
        assert!((fifo.makespan - (t_solo + t_c)).abs() < 1e-6);

        let pre = s.run_queue_arrivals(
            &queue,
            &[1, 0, 3],
            &[0.0, 0.0, arrive],
            &opts(Policy::PreemptLowest),
        );
        assert_eq!(pre.preemptions(), 2, "both low-priority jobs evicted");
        let cp = pre.jobs.iter().find(|j| j.id == 2).unwrap();
        assert!((cp.start - arrive).abs() < 1e-9, "C starts the moment it arrives");
        // A and B resume after C: remaining half plus one switch cost
        // each, in parallel on the two devices.
        let want = arrive + t_c + (t_solo - arrive) + 5.0;
        assert!(
            (pre.makespan - want).abs() < 1e-6,
            "makespan {} vs modeled {}",
            pre.makespan,
            want
        );
        // Priority was served: C finished far earlier than under FIFO.
        assert!(cp.end < cf.end);
    }
}
