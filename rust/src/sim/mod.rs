//! Discrete-event simulator: executes a planned job queue against a
//! modelled GPU pool at the paper's scale (8×A100-40G / 8×A10-24G,
//! Qwen/LLaMa-class geometries) — the machinery behind the Figure 4/5/6/7
//! and §6 reproductions.
//!
//! The simulator re-derives the timeline independently of the planner's
//! predictions: jobs launch FIFO when enough devices are free (the same
//! semantics as the live [`crate::session::Session`]), durations come from
//! the cost model optionally perturbed by lognormal noise (robustness
//! ablation — the planner plans on clean estimates, reality jitters).
//!
//! It speaks the session's language: every run emits the same
//! [`Event`] stream a live session does (`JobStarted`, `AdapterFinished`
//! at cost-model phase boundaries, `Rebucketed`, `JobFinished`), and the
//! per-job timeline in [`SimResult::jobs`] is reconstructed *from that
//! log* — so simulated and live traces can be compared or rendered by the
//! same consumers.

use std::collections::{BTreeMap, VecDeque};

use crate::costmodel::{CostModel, TrainBudget};
use crate::planner::PlannedJob;
use crate::session::Event;
use crate::util::rng::Rng;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Lognormal sigma applied to each job duration (0 = deterministic).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { noise: 0.0, seed: 42 }
    }
}

/// One simulated job execution.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: usize,
    pub d: usize,
    pub n_configs: usize,
    pub rank_sum: usize,
    pub start: f64,
    pub end: f64,
    pub devices: Vec<usize>,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Per-job timeline, reconstructed from the event log.
    pub jobs: Vec<SimJob>,
    pub makespan: f64,
    /// Busy seconds per device.
    pub device_busy: Vec<f64>,
    /// Scheduler decision points (completion events advanced past).
    pub events: usize,
    /// The session-compatible event stream of the whole run.
    pub log: Vec<Event>,
}

impl SimResult {
    /// Pool utilization: busy device-seconds over `G × makespan`.
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.device_busy.iter().sum::<f64>() / (self.device_busy.len() as f64 * self.makespan)
    }

    /// Aggregate rank-unit throughput (the Fig. 5/7 metric).
    pub fn rank_throughput(&self) -> f64 {
        let work: usize = self.jobs.iter().map(|j| j.rank_sum).sum();
        work as f64 / self.makespan.max(1e-9)
    }
}

/// The simulator.
pub struct Simulator {
    pub cm: CostModel,
    pub budget: TrainBudget,
    pub gpus: usize,
}

impl Simulator {
    pub fn new(cm: CostModel, gpus: usize) -> Simulator {
        Simulator { cm, budget: TrainBudget::default(), gpus }
    }

    /// Execute a job queue FIFO on the modelled pool.
    pub fn run_queue(&self, queue: &[PlannedJob], opts: &SimOptions) -> SimResult {
        let mut rng = Rng::new(opts.seed);
        let mut free: Vec<usize> = (0..self.gpus).collect();
        // (end_time, devices)
        let mut running: Vec<(f64, Vec<usize>)> = vec![];
        let mut pending: VecDeque<&PlannedJob> = queue.iter().collect();
        let mut now = 0.0f64;
        let mut log: Vec<Event> = vec![];
        let mut busy = vec![0.0f64; self.gpus];
        let mut events = 0usize;

        while !pending.is_empty() || !running.is_empty() {
            // FIFO launch while the head fits.
            while let Some(job) = pending.front() {
                if job.d <= free.len() {
                    let job = pending.pop_front().unwrap();
                    let devices: Vec<usize> = free.drain(..job.d).collect();
                    let phases = self.cm.job_phases(&job.pack, job.d, job.mode, &self.budget);
                    // Noise perturbs the whole job's duration once; phases
                    // stretch uniformly so boundary order is preserved.
                    let factor =
                        if opts.noise > 0.0 { (opts.noise * rng.normal()).exp() } else { 1.0 };
                    log.push(Event::JobStarted {
                        job: job.id,
                        n_adapters: job.pack.n(),
                        devices: devices.clone(),
                        at: now,
                    });
                    let mut shape =
                        (job.pack.n(), job.pack.r_pad(), job.pack.bs_pad());
                    let mut t = now;
                    for p in &phases {
                        t += p.dur * factor;
                        for &id in &p.finished {
                            log.push(Event::AdapterFinished {
                                job: job.id,
                                adapter: id,
                                task: String::new(),
                                steps: 0,
                                eval_loss: f32::NAN,
                                eval_acc: f32::NAN,
                                at: t,
                            });
                        }
                        if p.survivors.0 > 0 && p.survivors != shape {
                            log.push(Event::Rebucketed {
                                job: job.id,
                                from: shape,
                                to: p.survivors,
                                survivors: vec![],
                                at: t,
                            });
                            shape = p.survivors;
                        }
                    }
                    let dur = t - now;
                    for &dev in &devices {
                        busy[dev] += dur;
                    }
                    log.push(Event::JobFinished {
                        job: job.id,
                        adapters: job.pack.n(),
                        wall: dur,
                        at: t,
                    });
                    running.push((t, devices));
                } else {
                    break;
                }
            }
            if running.is_empty() {
                if pending.is_empty() {
                    break;
                }
                // Head job larger than the pool: impossible queue.
                panic!(
                    "sim: job {} wants {} devices, pool has {}",
                    pending[0].id, pending[0].d, self.gpus
                );
            }
            // Advance to the earliest completion.
            events += 1;
            let (idx, _) = running
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
                .unwrap();
            let (end, devices) = running.swap_remove(idx);
            now = end.max(now);
            free.extend(devices);
            free.sort_unstable();
        }

        // Order the log by timestamp so it reads like a live session's
        // stream (job event chains are generated at admission time, so
        // concurrent jobs would otherwise interleave out of order); the
        // stable sort keeps same-instant events in emission order.
        log.sort_by(|a, b| a.at().total_cmp(&b.at()));

        // The timeline is read back off the event log (same stream a live
        // session emits), joined with the queue's static job facts.
        let by_id: BTreeMap<usize, &PlannedJob> = queue.iter().map(|j| (j.id, j)).collect();
        let mut jobs: Vec<SimJob> = vec![];
        let mut open: BTreeMap<usize, usize> = BTreeMap::new(); // job id -> index
        for ev in &log {
            match ev {
                Event::JobStarted { job, devices, at, .. } => {
                    let pj = by_id[job];
                    open.insert(*job, jobs.len());
                    jobs.push(SimJob {
                        id: *job,
                        d: pj.d,
                        n_configs: pj.pack.n(),
                        rank_sum: pj.pack.rank_sum(),
                        start: *at,
                        end: *at,
                        devices: devices.clone(),
                    });
                }
                Event::JobFinished { job, at, .. } => {
                    if let Some(&i) = open.get(job) {
                        jobs[i].end = *at;
                    }
                }
                _ => {}
            }
        }

        let makespan = jobs.iter().map(|j| j.end).fold(0.0, f64::max);
        SimResult { jobs, makespan, device_busy: busy, events, log }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::{LoraConfig, SearchSpace};
    use crate::costmodel::{ExecMode, Pack};
    use crate::planner::{min_gpu_plan, JobPlanner};

    fn sim(model: &str) -> Simulator {
        Simulator::new(CostModel::new(geom(model).unwrap(), &A100_40G), 8)
    }

    #[test]
    fn sim_agrees_with_planner_prediction_when_deterministic() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        let rel = (res.makespan - plan.makespan).abs() / plan.makespan;
        assert!(
            rel < 0.05,
            "sim {:.0}s vs plan {:.0}s ({:.1}% off)",
            res.makespan,
            plan.makespan,
            rel * 100.0
        );
    }

    #[test]
    fn devices_never_oversubscribed() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        // At any event point, active jobs' devices must be disjoint.
        let points: Vec<f64> = res.jobs.iter().map(|j| j.start + 1e-6).collect();
        for &t in &points {
            let mut used = std::collections::BTreeSet::new();
            for j in res.jobs.iter().filter(|j| j.start <= t && t < j.end) {
                for &d in &j.devices {
                    assert!(used.insert(d), "device {d} double-booked at t={t}");
                }
            }
            assert!(used.len() <= 8);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_feasibility() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let clean = s.run_queue(&queue, &SimOptions::default());
        let noisy = s.run_queue(&queue, &SimOptions { noise: 0.2, seed: 7 });
        assert!(noisy.makespan != clean.makespan);
        assert!((noisy.makespan / clean.makespan - 1.0).abs() < 0.5);
        assert_eq!(noisy.jobs.len(), clean.jobs.len());
    }

    #[test]
    fn utilization_and_throughput_positive() {
        let s = sim("qwen2.5-3b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        assert!(res.utilization() > 0.5 && res.utilization() <= 1.0);
        assert!(res.rank_throughput() > 0.0);
    }

    /// A mixed-batch pack produces the session event vocabulary: started,
    /// adapter-finished at phase boundaries, re-bucketed, finished — and
    /// the job timeline in `jobs` is exactly what the log says.
    #[test]
    fn event_log_carries_phases_and_rebuckets() {
        let s = sim("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let queue = vec![PlannedJob {
            id: 0,
            pack: Pack::new(vec![cfg(0, 1), cfg(1, 4)]),
            d: 1,
            mode: ExecMode::Packed,
        }];
        let res = s.run_queue(&queue, &SimOptions::default());
        let kinds: Vec<&str> = res
            .log
            .iter()
            .map(|e| match e {
                Event::JobStarted { .. } => "started",
                Event::AdapterFinished { .. } => "adapter",
                Event::Rebucketed { .. } => "rebucket",
                Event::JobFinished { .. } => "finished",
                Event::JobFailed { .. } => "failed",
                Event::CalibUpdated { .. } => "calib",
            })
            .collect();
        assert_eq!(kinds, vec!["started", "adapter", "rebucket", "adapter", "finished"]);
        // bs4 (fewer steps) leaves first; survivors shrink to (1, 16, 1).
        let Some(Event::Rebucketed { from, to, .. }) =
            res.log.iter().find(|e| matches!(e, Event::Rebucketed { .. }))
        else {
            panic!("no rebucket event");
        };
        assert_eq!(*from, (2, 16, 4));
        assert_eq!(*to, (1, 16, 1));
        // Timeline rebuilt from the log matches the cost model exactly.
        assert_eq!(res.jobs.len(), 1);
        let want = s.cm.job_time(&queue[0].pack, 1, ExecMode::Packed, &s.budget);
        assert!((res.jobs[0].end - res.jobs[0].start - want).abs() < 1e-9);
        // Event timestamps are monotone.
        for w in res.log.windows(2) {
            assert!(w[0].at() <= w[1].at() + 1e-12);
        }
    }
}
