//! Discrete-event simulator: executes a planned job queue against a
//! modelled GPU pool at the paper's scale (8×A100-40G / 8×A10-24G,
//! Qwen/LLaMa-class geometries) — the machinery behind the Figure 4/5/6/7
//! and §6 reproductions.
//!
//! The simulator re-derives the timeline independently of the planner's
//! predictions: jobs launch under the same [`Policy`] vocabulary as the
//! live [`crate::session::Session`] (FIFO head-of-line, priority
//! backfill, or strict priority with preemption), may carry **arrival
//! times** (skewed-arrival scenarios), durations come from the cost model
//! optionally perturbed by lognormal noise (robustness ablation — the
//! planner plans on clean estimates, reality jitters), and every
//! preemption-resume charges the cost model's `bucket_switch_cost` term —
//! the same penalty the live retarget planner weighs (as does every
//! mid-job bucket switch).
//!
//! It speaks the session's language: every run emits the same
//! [`Event`] stream a live session does (`JobStarted`, `AdapterFinished`
//! at cost-model phase boundaries, `Rebucketed`, `Preempted`,
//! `JobFinished`), and the per-job timeline in [`SimResult::jobs`] is
//! reconstructed *from that log* — so simulated and live traces can be
//! compared or rendered by the same consumers.

use std::collections::BTreeMap;

use crate::costmodel::{CostModel, JobPhase, TrainBudget};
use crate::planner::PlannedJob;
use crate::session::{Event, Policy};
use crate::util::rng::Rng;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Lognormal sigma applied to each job duration (0 = deterministic).
    pub noise: f64,
    pub seed: u64,
    /// Queue dispatch policy (the session's vocabulary).
    pub policy: Policy,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { noise: 0.0, seed: 42, policy: Policy::Fifo }
    }
}

/// One simulated job execution.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: usize,
    pub d: usize,
    pub n_configs: usize,
    pub rank_sum: usize,
    pub start: f64,
    pub end: f64,
    pub devices: Vec<usize>,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Per-job timeline, reconstructed from the event log (first launch
    /// to final finish for preempted-and-resumed jobs).
    pub jobs: Vec<SimJob>,
    pub makespan: f64,
    /// Busy seconds per device.
    pub device_busy: Vec<f64>,
    /// Scheduler decision points (phase / arrival / preemption events
    /// advanced past).
    pub events: usize,
    /// The session-compatible event stream of the whole run.
    pub log: Vec<Event>,
}

impl SimResult {
    /// Pool utilization: busy device-seconds over `G × makespan`.
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.device_busy.iter().sum::<f64>() / (self.device_busy.len() as f64 * self.makespan)
    }

    /// Aggregate rank-unit throughput (the Fig. 5/7 metric).
    pub fn rank_throughput(&self) -> f64 {
        let work: usize = self.jobs.iter().map(|j| j.rank_sum).sum();
        work as f64 / self.makespan.max(1e-9)
    }

    /// Number of `Preempted` events in the log.
    pub fn preemptions(&self) -> usize {
        self.log.iter().filter(|e| matches!(e, Event::Preempted { .. })).count()
    }
}

/// One queued (or preempted-and-requeued) job awaiting devices.
struct Pend {
    qi: usize,
    seq: usize,
    prio: i32,
    arrive: f64,
    /// Remaining phases + partial progress of a preempted job.
    resume: Option<ResumeSim>,
}

struct ResumeSim {
    phases: Vec<JobPhase>,
    next: usize,
    /// Seconds left of phase `next` when the job was preempted.
    partial_left: f64,
    shape: (usize, usize, usize),
    factor: f64,
}

/// One job currently holding devices.
struct Run {
    qi: usize,
    seq: usize,
    prio: i32,
    devices: Vec<usize>,
    phases: Vec<JobPhase>,
    next: usize,
    phase_end: f64,
    shape: (usize, usize, usize),
    factor: f64,
    seg_start: f64,
}

/// The simulator.
pub struct Simulator {
    pub cm: CostModel,
    pub budget: TrainBudget,
    pub gpus: usize,
}

impl Simulator {
    pub fn new(cm: CostModel, gpus: usize) -> Simulator {
        Simulator { cm, budget: TrainBudget::default(), gpus }
    }

    /// Execute a job queue on the modelled pool under `opts.policy` with
    /// all priorities 0 and simultaneous arrival.
    pub fn run_queue(&self, queue: &[PlannedJob], opts: &SimOptions) -> SimResult {
        self.run_queue_prio(queue, &[], opts)
    }

    /// Execute with explicit per-job priorities (`prios[i]` belongs to
    /// `queue[i]`; missing entries are 0), simultaneous arrival.
    pub fn run_queue_prio(
        &self,
        queue: &[PlannedJob],
        prios: &[i32],
        opts: &SimOptions,
    ) -> SimResult {
        self.run_queue_arrivals(queue, prios, &[], opts)
    }

    /// The full policy path: per-job priorities and arrival times
    /// (`arrivals[i]` seconds; missing entries arrive at 0). A job is
    /// invisible to the dispatcher before its arrival — the skewed-arrival
    /// scenarios where priority and preemption earn their keep.
    pub fn run_queue_arrivals(
        &self,
        queue: &[PlannedJob],
        prios: &[i32],
        arrivals: &[f64],
        opts: &SimOptions,
    ) -> SimResult {
        let mut rng = Rng::new(opts.seed);
        let switch_cost = self.cm.calib.bucket_switch_cost;
        let mut free: Vec<usize> = (0..self.gpus).collect();
        let mut pending: Vec<Pend> = queue
            .iter()
            .enumerate()
            .map(|(i, _)| Pend {
                qi: i,
                seq: i,
                prio: prios.get(i).copied().unwrap_or(0),
                arrive: arrivals.get(i).copied().unwrap_or(0.0),
                resume: None,
            })
            .collect();
        let mut running: Vec<Run> = vec![];
        let mut now = 0.0f64;
        let mut log: Vec<Event> = vec![];
        let mut busy = vec![0.0f64; self.gpus];
        let mut events = 0usize;

        // Next launchable pending index under the policy, among arrived
        // jobs. FIFO and PreemptLowest block on their head (submission /
        // priority order); Priority backfills past a too-big head.
        let pick = |pending: &[Pend], now: f64, avail: usize| -> Option<usize> {
            let arrived = |p: &Pend| p.arrive <= now + 1e-12;
            match opts.policy {
                Policy::Fifo => {
                    let (idx, head) = pending
                        .iter()
                        .enumerate()
                        .filter(|(_, p)| arrived(p))
                        .min_by_key(|(_, p)| p.seq)?;
                    (queue[head.qi].d <= avail).then_some(idx)
                }
                Policy::Priority => {
                    let mut order: Vec<usize> = (0..pending.len())
                        .filter(|&i| arrived(&pending[i]))
                        .collect();
                    order.sort_by_key(|&i| (std::cmp::Reverse(pending[i].prio), pending[i].seq));
                    order.into_iter().find(|&i| queue[pending[i].qi].d <= avail)
                }
                Policy::PreemptLowest => {
                    // Strict priority: never backfill past a starved
                    // higher-priority job (its devices are being vacated —
                    // backfilling would re-occupy them and livelock).
                    let (idx, head) = pending
                        .iter()
                        .enumerate()
                        .filter(|(_, p)| arrived(p))
                        .min_by_key(|(_, p)| (std::cmp::Reverse(p.prio), p.seq))?;
                    (queue[head.qi].d <= avail).then_some(idx)
                }
            }
        };

        while !pending.is_empty() || !running.is_empty() {
            // Launch while the policy grants devices.
            while let Some(idx) = pick(&pending, now, free.len()) {
                let p = pending.remove(idx);
                let job = &queue[p.qi];
                let devices: Vec<usize> = free.drain(..job.d).collect();
                let (phases, next, first_dur, shape, factor) = match p.resume {
                    Some(r) => {
                        // Resuming pays the restore side of the switch.
                        (r.phases, r.next, r.partial_left + switch_cost, r.shape, r.factor)
                    }
                    None => {
                        let phases = self.cm.job_phases(&job.pack, job.d, job.mode, &self.budget);
                        // Noise perturbs the whole job's duration once;
                        // phases stretch uniformly so boundary order is
                        // preserved.
                        let factor = if opts.noise > 0.0 {
                            (opts.noise * rng.normal()).exp()
                        } else {
                            1.0
                        };
                        let shape = (job.pack.n(), job.pack.r_pad(), job.pack.bs_pad());
                        let d0 = phases.first().map(|p| p.dur * factor).unwrap_or(0.0);
                        (phases, 0usize, d0, shape, factor)
                    }
                };
                log.push(Event::JobStarted {
                    job: job.id,
                    n_adapters: job.pack.n(),
                    devices: devices.clone(),
                    at: now,
                });
                let first_dur = if next >= phases.len() { 0.0 } else { first_dur };
                running.push(Run {
                    qi: p.qi,
                    seq: p.seq,
                    prio: p.prio,
                    devices,
                    phases,
                    next,
                    phase_end: now + first_dur,
                    shape,
                    factor,
                    seg_start: now,
                });
            }

            // Preemption: a starved higher-priority job evicts strictly
            // lower-priority running jobs — but only when evicting enough
            // of them actually frees what it needs.
            if opts.policy == Policy::PreemptLowest {
                let starved = pending
                    .iter()
                    .filter(|p| p.arrive <= now + 1e-12)
                    .min_by_key(|p| (std::cmp::Reverse(p.prio), p.seq))
                    .map(|p| (p.prio, queue[p.qi].d));
                if let Some((top_prio, need)) = starved {
                    let takeable: usize = running
                        .iter()
                        .filter(|r| r.prio < top_prio)
                        .map(|r| r.devices.len())
                        .sum();
                    if need > free.len() && free.len() + takeable >= need {
                        // Evict lowest-priority victims until it fits.
                        while free.len() < need {
                            let (vi, _) = running
                                .iter()
                                .enumerate()
                                .filter(|(_, r)| r.prio < top_prio)
                                .min_by_key(|(_, r)| (r.prio, std::cmp::Reverse(r.seq)))
                                .expect("takeable victims verified above");
                            events += 1;
                            let r = running.swap_remove(vi);
                            let job = &queue[r.qi];
                            for &dev in &r.devices {
                                busy[dev] += now - r.seg_start;
                            }
                            free.extend(r.devices);
                            free.sort_unstable();
                            let prior = &r.phases[..r.next];
                            let done_ids: std::collections::BTreeSet<usize> =
                                prior.iter().flat_map(|p| p.finished.iter().copied()).collect();
                            let remaining: Vec<usize> = job
                                .pack
                                .configs
                                .iter()
                                .map(|c| c.id)
                                .filter(|id| !done_ids.contains(id))
                                .collect();
                            log.push(Event::Preempted {
                                job: job.id,
                                adapters: remaining,
                                at: now,
                            });
                            pending.push(Pend {
                                qi: r.qi,
                                seq: r.seq,
                                prio: r.prio,
                                arrive: now,
                                resume: Some(ResumeSim {
                                    partial_left: (r.phase_end - now).max(0.0),
                                    phases: r.phases,
                                    next: r.next,
                                    shape: r.shape,
                                    factor: r.factor,
                                }),
                            });
                        }
                        continue; // re-run launches at the same instant
                    }
                }
            }

            // Next event: the earliest phase boundary or job arrival.
            let next_phase = running.iter().map(|r| r.phase_end).fold(f64::INFINITY, f64::min);
            let next_arrival = pending
                .iter()
                .map(|p| p.arrive)
                .filter(|&a| a > now + 1e-12)
                .fold(f64::INFINITY, f64::min);
            if running.is_empty() {
                if pending.is_empty() {
                    break;
                }
                if next_arrival.is_finite() {
                    events += 1;
                    now = next_arrival;
                    continue;
                }
                // Arrived head larger than the whole pool: impossible.
                let hd = pending
                    .iter()
                    .min_by_key(|p| (std::cmp::Reverse(p.prio), p.seq))
                    .unwrap();
                let j = &queue[hd.qi];
                panic!("sim: job {} wants {} devices, pool has {}", j.id, j.d, self.gpus);
            }
            if next_arrival < next_phase {
                events += 1;
                now = next_arrival;
                continue;
            }

            // Advance to the earliest phase boundary.
            events += 1;
            let (idx, _) = running
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.phase_end.total_cmp(&b.1.phase_end))
                .unwrap();
            now = running[idx].phase_end.max(now);
            let finished_job = {
                let r = &mut running[idx];
                let job = &queue[r.qi];
                if r.next < r.phases.len() {
                    let p = r.phases[r.next].clone();
                    for &id in &p.finished {
                        log.push(Event::AdapterFinished {
                            job: job.id,
                            adapter: id,
                            task: String::new(),
                            steps: 0,
                            eval_loss: f32::NAN,
                            eval_acc: f32::NAN,
                            at: now,
                        });
                    }
                    let mut switch_pay = 0.0;
                    if p.survivors.0 > 0 && p.survivors != r.shape {
                        log.push(Event::Rebucketed {
                            job: job.id,
                            from: r.shape,
                            to: p.survivors,
                            survivors: vec![],
                            at: now,
                        });
                        r.shape = p.survivors;
                        switch_pay = switch_cost;
                    }
                    r.next += 1;
                    if r.next < r.phases.len() {
                        r.phase_end = now + switch_pay + r.phases[r.next].dur * r.factor;
                        false
                    } else {
                        true
                    }
                } else {
                    true
                }
            };
            if finished_job {
                let r = running.swap_remove(idx);
                let job = &queue[r.qi];
                for &dev in &r.devices {
                    busy[dev] += now - r.seg_start;
                }
                log.push(Event::JobFinished {
                    job: job.id,
                    adapters: job.pack.n(),
                    wall: now - r.seg_start,
                    at: now,
                });
                free.extend(r.devices);
                free.sort_unstable();
            }
        }

        // Order the log by timestamp so it reads like a live session's
        // stream; the stable sort keeps same-instant events in emission
        // order.
        log.sort_by(|a, b| a.at().total_cmp(&b.at()));

        // The timeline is read back off the event log (same stream a live
        // session emits), joined with the queue's static job facts. A
        // preempted job's SimJob spans first launch to final finish.
        let by_id: BTreeMap<usize, &PlannedJob> = queue.iter().map(|j| (j.id, j)).collect();
        let mut jobs: Vec<SimJob> = vec![];
        let mut open: BTreeMap<usize, usize> = BTreeMap::new(); // job id -> index
        for ev in &log {
            match ev {
                Event::JobStarted { job, devices, at, .. } => {
                    if let Some(&i) = open.get(job) {
                        jobs[i].devices = devices.clone();
                        continue;
                    }
                    let pj = by_id[job];
                    open.insert(*job, jobs.len());
                    jobs.push(SimJob {
                        id: *job,
                        d: pj.d,
                        n_configs: pj.pack.n(),
                        rank_sum: pj.pack.rank_sum(),
                        start: *at,
                        end: *at,
                        devices: devices.clone(),
                    });
                }
                Event::JobFinished { job, at, .. } => {
                    if let Some(&i) = open.get(job) {
                        jobs[i].end = *at;
                    }
                }
                _ => {}
            }
        }

        let makespan = jobs.iter().map(|j| j.end).fold(0.0, f64::max);
        SimResult { jobs, makespan, device_busy: busy, events, log }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::{LoraConfig, SearchSpace};
    use crate::costmodel::{ExecMode, Pack};
    use crate::planner::{min_gpu_plan, JobPlanner};

    fn sim(model: &str) -> Simulator {
        Simulator::new(CostModel::new(geom(model).unwrap(), &A100_40G), 8)
    }

    #[test]
    fn sim_agrees_with_planner_prediction_when_deterministic() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        let rel = (res.makespan - plan.makespan).abs() / plan.makespan;
        assert!(
            rel < 0.05,
            "sim {:.0}s vs plan {:.0}s ({:.1}% off)",
            res.makespan,
            plan.makespan,
            rel * 100.0
        );
    }

    #[test]
    fn devices_never_oversubscribed() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        // At any event point, active jobs' devices must be disjoint.
        let points: Vec<f64> = res.jobs.iter().map(|j| j.start + 1e-6).collect();
        for &t in &points {
            let mut used = std::collections::BTreeSet::new();
            for j in res.jobs.iter().filter(|j| j.start <= t && t < j.end) {
                for &d in &j.devices {
                    assert!(used.insert(d), "device {d} double-booked at t={t}");
                }
            }
            assert!(used.len() <= 8);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_feasibility() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let clean = s.run_queue(&queue, &SimOptions::default());
        let noisy = s.run_queue(&queue, &SimOptions { noise: 0.2, seed: 7, ..Default::default() });
        assert!(noisy.makespan != clean.makespan);
        assert!((noisy.makespan / clean.makespan - 1.0).abs() < 0.5);
        assert_eq!(noisy.jobs.len(), clean.jobs.len());
    }

    #[test]
    fn utilization_and_throughput_positive() {
        let s = sim("qwen2.5-3b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        assert!(res.utilization() > 0.5 && res.utilization() <= 1.0);
        assert!(res.rank_throughput() > 0.0);
    }

    /// A mixed-batch pack produces the session event vocabulary: started,
    /// adapter-finished at phase boundaries, re-bucketed, finished — and
    /// the job timeline in `jobs` is exactly what the log says.
    #[test]
    fn event_log_carries_phases_and_rebuckets() {
        let s = sim("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let queue = vec![PlannedJob {
            id: 0,
            pack: Pack::new(vec![cfg(0, 1), cfg(1, 4)]),
            d: 1,
            mode: ExecMode::Packed,
        }];
        let res = s.run_queue(&queue, &SimOptions::default());
        let kinds: Vec<&str> = res
            .log
            .iter()
            .map(|e| match e {
                Event::JobStarted { .. } => "started",
                Event::AdapterFinished { .. } => "adapter",
                Event::AdapterAdmitted { .. } => "admitted",
                Event::Rebucketed { .. } => "rebucket",
                Event::Preempted { .. } => "preempted",
                Event::JobFinished { .. } => "finished",
                Event::JobFailed { .. } => "failed",
                Event::CalibUpdated { .. } => "calib",
            })
            .collect();
        assert_eq!(kinds, vec!["started", "adapter", "rebucket", "adapter", "finished"]);
        // bs4 (fewer steps) leaves first; survivors shrink to (1, 16, 1).
        let Some(Event::Rebucketed { from, to, .. }) =
            res.log.iter().find(|e| matches!(e, Event::Rebucketed { .. }))
        else {
            panic!("no rebucket event");
        };
        assert_eq!(*from, (2, 16, 4));
        assert_eq!(*to, (1, 16, 1));
        // Timeline rebuilt from the log matches the cost model exactly.
        assert_eq!(res.jobs.len(), 1);
        let want = s.cm.job_time(&queue[0].pack, 1, ExecMode::Packed, &s.budget);
        assert!((res.jobs[0].end - res.jobs[0].start - want).abs() < 1e-9);
        // Event timestamps are monotone.
        for w in res.log.windows(2) {
            assert!(w[0].at() <= w[1].at() + 1e-12);
        }
    }

    /// The policy path on a skewed arrival: a high-priority job arriving
    /// mid-run evicts both lower-priority running jobs under
    /// `PreemptLowest` (two `Preempted` events, resumes charged one
    /// `bucket_switch_cost` each); under FIFO it simply waits. Work is
    /// conserved either way.
    #[test]
    fn preempt_lowest_evicts_on_late_high_priority_arrival() {
        let mut s = sim("qwen2.5-7b");
        s.gpus = 2;
        s.cm.calib.bucket_switch_cost = 5.0;
        let cfg = |id: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: 1,
            rank: 16,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let job = |id: usize, c0: usize, d: usize| PlannedJob {
            id,
            pack: Pack::new(vec![cfg(c0)]),
            d,
            mode: ExecMode::Packed,
        };
        // A and B run on one device each; C (d=2, high priority) arrives
        // mid-run and needs the whole pool.
        let queue = vec![job(0, 0, 1), job(1, 1, 1), job(2, 2, 2)];
        let t_solo = s.cm.job_time(&queue[0].pack, 1, ExecMode::Packed, &s.budget);
        let t_c = s.cm.job_time(&queue[2].pack, 2, ExecMode::Packed, &s.budget);
        let arrive = t_solo * 0.5;
        let opts = |policy| SimOptions { policy, ..Default::default() };

        let fifo = s.run_queue_arrivals(
            &queue,
            &[1, 0, 3],
            &[0.0, 0.0, arrive],
            &opts(Policy::Fifo),
        );
        assert_eq!(fifo.preemptions(), 0);
        let cf = fifo.jobs.iter().find(|j| j.id == 2).unwrap();
        assert!((cf.start - t_solo).abs() < 1e-9, "FIFO: C waits for both to finish");
        assert!((fifo.makespan - (t_solo + t_c)).abs() < 1e-6);

        let pre = s.run_queue_arrivals(
            &queue,
            &[1, 0, 3],
            &[0.0, 0.0, arrive],
            &opts(Policy::PreemptLowest),
        );
        assert_eq!(pre.preemptions(), 2, "both low-priority jobs evicted");
        let cp = pre.jobs.iter().find(|j| j.id == 2).unwrap();
        assert!((cp.start - arrive).abs() < 1e-9, "C starts the moment it arrives");
        // A and B resume after C: remaining half plus one switch cost
        // each, in parallel on the two devices.
        let want = arrive + t_c + (t_solo - arrive) + 5.0;
        assert!(
            (pre.makespan - want).abs() < 1e-6,
            "makespan {} vs modeled {}",
            pre.makespan,
            want
        );
        // Priority was served: C finished far earlier than under FIFO.
        assert!(cp.end < cf.end);
    }
}
