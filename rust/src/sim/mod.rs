//! Discrete-event simulator: executes a planned job queue against a
//! modelled GPU pool at the paper's scale (8×A100-40G / 8×A10-24G,
//! Qwen/LLaMa-class geometries) — the machinery behind the Figure 4/5/6/7
//! and §6 reproductions.
//!
//! The simulator re-derives the timeline independently of the planner's
//! predictions: jobs launch FIFO when enough devices are free (the same
//! semantics as the live [`crate::engine::Engine`]), durations come from
//! the cost model optionally perturbed by lognormal noise (robustness
//! ablation — the planner plans on clean estimates, reality jitters).

use std::collections::VecDeque;

use crate::costmodel::{CostModel, TrainBudget};
use crate::planner::PlannedJob;
use crate::util::rng::Rng;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Lognormal sigma applied to each job duration (0 = deterministic).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { noise: 0.0, seed: 42 }
    }
}

/// One simulated job execution.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: usize,
    pub d: usize,
    pub n_configs: usize,
    pub rank_sum: usize,
    pub start: f64,
    pub end: f64,
    pub devices: Vec<usize>,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub jobs: Vec<SimJob>,
    pub makespan: f64,
    /// Busy seconds per device.
    pub device_busy: Vec<f64>,
    pub events: usize,
}

impl SimResult {
    /// Pool utilization: busy device-seconds over `G × makespan`.
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.device_busy.iter().sum::<f64>() / (self.device_busy.len() as f64 * self.makespan)
    }

    /// Aggregate rank-unit throughput (the Fig. 5/7 metric).
    pub fn rank_throughput(&self) -> f64 {
        let work: usize = self.jobs.iter().map(|j| j.rank_sum).sum();
        work as f64 / self.makespan.max(1e-9)
    }
}

/// The simulator.
pub struct Simulator {
    pub cm: CostModel,
    pub budget: TrainBudget,
    pub gpus: usize,
}

impl Simulator {
    pub fn new(cm: CostModel, gpus: usize) -> Simulator {
        Simulator { cm, budget: TrainBudget::default(), gpus }
    }

    /// Execute a job queue FIFO on the modelled pool.
    pub fn run_queue(&self, queue: &[PlannedJob], opts: &SimOptions) -> SimResult {
        let mut rng = Rng::new(opts.seed);
        let mut free: Vec<usize> = (0..self.gpus).collect();
        // (end_time, devices)
        let mut running: Vec<(f64, Vec<usize>)> = vec![];
        let mut pending: VecDeque<&PlannedJob> = queue.iter().collect();
        let mut now = 0.0f64;
        let mut out = vec![];
        let mut busy = vec![0.0f64; self.gpus];
        let mut events = 0usize;

        while !pending.is_empty() || !running.is_empty() {
            // FIFO launch while the head fits.
            while let Some(job) = pending.front() {
                if job.d <= free.len() {
                    let job = pending.pop_front().unwrap();
                    let devices: Vec<usize> = free.drain(..job.d).collect();
                    let mut dur = self.cm.job_time(&job.pack, job.d, job.mode, &self.budget);
                    if opts.noise > 0.0 {
                        dur *= (opts.noise * rng.normal()).exp();
                    }
                    for &dev in &devices {
                        busy[dev] += dur;
                    }
                    out.push(SimJob {
                        id: job.id,
                        d: job.d,
                        n_configs: job.pack.n(),
                        rank_sum: job.pack.rank_sum(),
                        start: now,
                        end: now + dur,
                        devices: devices.clone(),
                    });
                    running.push((now + dur, devices));
                } else {
                    break;
                }
            }
            if running.is_empty() {
                if pending.is_empty() {
                    break;
                }
                // Head job larger than the pool: impossible queue.
                panic!(
                    "sim: job {} wants {} devices, pool has {}",
                    pending[0].id, pending[0].d, self.gpus
                );
            }
            // Advance to the earliest completion.
            events += 1;
            let (idx, _) = running
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
                .unwrap();
            let (end, devices) = running.swap_remove(idx);
            now = end.max(now);
            free.extend(devices);
            free.sort_unstable();
        }

        let makespan = out.iter().map(|j| j.end).fold(0.0, f64::max);
        SimResult { jobs: out, makespan, device_busy: busy, events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::SearchSpace;
    use crate::planner::{min_gpu_plan, JobPlanner};

    fn sim(model: &str) -> Simulator {
        Simulator::new(CostModel::new(geom(model).unwrap(), &A100_40G), 8)
    }

    #[test]
    fn sim_agrees_with_planner_prediction_when_deterministic() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        let rel = (res.makespan - plan.makespan).abs() / plan.makespan;
        assert!(
            rel < 0.05,
            "sim {:.0}s vs plan {:.0}s ({:.1}% off)",
            res.makespan,
            plan.makespan,
            rel * 100.0
        );
    }

    #[test]
    fn devices_never_oversubscribed() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        // At any event point, active jobs' devices must be disjoint.
        let points: Vec<f64> = res.jobs.iter().map(|j| j.start + 1e-6).collect();
        for &t in &points {
            let mut used = std::collections::BTreeSet::new();
            for j in res.jobs.iter().filter(|j| j.start <= t && t < j.end) {
                for &d in &j.devices {
                    assert!(used.insert(d), "device {d} double-booked at t={t}");
                }
            }
            assert!(used.len() <= 8);
        }
    }

    #[test]
    fn noise_perturbs_but_preserves_feasibility() {
        let s = sim("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&s.cm, &s.budget, 8, &grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let clean = s.run_queue(&queue, &SimOptions::default());
        let noisy = s.run_queue(&queue, &SimOptions { noise: 0.2, seed: 7 });
        assert!(noisy.makespan != clean.makespan);
        assert!((noisy.makespan / clean.makespan - 1.0).abs() < 0.5);
        assert_eq!(noisy.jobs.len(), clean.jobs.len());
    }

    #[test]
    fn utilization_and_throughput_positive() {
        let s = sim("qwen2.5-3b");
        let grid = SearchSpace::default().grid("t");
        let plan = JobPlanner::new(s.cm.clone(), 8).plan(&grid).unwrap();
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let res = s.run_queue(&queue, &SimOptions::default());
        assert!(res.utilization() > 0.5 && res.utilization() <= 1.0);
        assert!(res.rank_throughput() > 0.0);
    }
}
