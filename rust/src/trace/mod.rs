//! Deterministic session **record/replay** (DESIGN.md §12).
//!
//! Bit-identical adapter trajectories are the repo's superpower: every
//! adapter trains the same whether it runs solo, packed, admitted mid-job,
//! preempted-and-resumed, or sharded across any device count. This module
//! makes that invariant a product feature:
//!
//! - [`TraceRecorder`] captures a session's full provenance — the settings
//!   snapshot (model, pool size, policy, elastic/rebucket knobs, training
//!   options, device-env knobs), every submitted job (ids, priorities,
//!   `d`, exec mode, adapter configs), the ordered [`Event`] stream with
//!   wall-clock timestamps, and a [`SessionDigest`] of the final
//!   [`SessionReport`] — into a versioned on-disk [`Trace`]
//!   (`plora sweep/serve --record <path>`).
//! - [`replay`] re-executes a loaded trace through a **real** [`Session`]
//!   and compares digests bit-for-bit (`plora replay <path>`).
//! - [`replay_timing`] rebuilds the *timeline* only, through the
//!   simulator's cost model — offline scheduler debugging without paying
//!   for training (`plora replay <path> --sim`).
//!
//! **What must match and what may not.** Wall-clock timings, event
//! interleavings and job-hosting structure (which running pack absorbs a
//! queued adapter, whether a preemption actually fires) race under
//! multi-device elastic execution and are *recorded provenance*, not
//! replay obligations. The deterministic contract is per-adapter: steps,
//! every loss/accuracy, the loss curve, and the final LoRA parameters.
//! [`SessionDigest`] therefore keys by adapter id and stores f32 **bit
//! patterns** (plus an FNV-1a hash of the final params computed by the
//! driver at each adapter's finish boundary), so "equal" means equal to
//! the last bit, NaNs included.

pub mod perf;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::ResourceMonitor;
use crate::config::{pool, LoraConfig};
use crate::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use crate::engine::CheckpointPool;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::search::{Asha, SweepOptions, Tuner};
use crate::session::{Event, Policy, Session, SessionReport};
use crate::sim::{SimOptions, SimResult, Simulator};
use crate::train::{AdapterReport, TrainOptions};
use crate::util::hash::Fnv64;
use crate::util::json::Json;

/// On-disk trace schema version. Bump on any incompatible layout change;
/// [`Trace::load`] refuses files from a different version with a clear
/// error instead of misreading them.
pub const TRACE_SCHEMA: u64 = 1;

// ---------------------------------------------------------------------------
// Digest
// ---------------------------------------------------------------------------

/// The deterministic projection of one adapter's outcome: identity fields
/// plus every trajectory quantity as an exact bit pattern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdapterDigest {
    pub task: String,
    pub rank: usize,
    pub batch: usize,
    pub lr_bits: u64,
    pub steps: usize,
    pub first_loss: u32,
    pub final_loss: u32,
    pub base_loss: u32,
    pub base_acc: u32,
    pub eval_loss: u32,
    pub eval_acc: u32,
    /// FNV-1a over the final LoRA parameters at true rank
    /// ([`crate::runtime::MemberState::param_hash`]).
    pub param_hash: u64,
    pub curve: Vec<(usize, u32)>,
}

impl AdapterDigest {
    /// The deterministic projection of one finished adapter's report —
    /// what the daemon journals at each adapter's finish boundary so a
    /// crashed process can still account for completed work bit-exactly.
    pub fn of_report(a: &AdapterReport) -> AdapterDigest {
        AdapterDigest {
            task: a.config.task.clone(),
            rank: a.config.rank,
            batch: a.config.batch,
            lr_bits: a.config.lr.to_bits(),
            steps: a.steps,
            first_loss: a.first_loss.to_bits(),
            final_loss: a.final_loss.to_bits(),
            base_loss: a.base_loss.to_bits(),
            base_acc: a.base_acc.to_bits(),
            eval_loss: a.eval_loss.to_bits(),
            eval_acc: a.eval_acc.to_bits(),
            param_hash: a.param_hash,
            curve: a.curve.iter().map(|&(s, l)| (s, l.to_bits())).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        adapter_to_json(self)
    }

    pub fn from_json(v: &Json) -> Result<AdapterDigest> {
        adapter_from_json(v)
    }
}

/// Adapter-id-keyed digest of a [`SessionReport`] — the bitwise equality
/// the replayer asserts. Identical regardless of which job hosted each
/// adapter or in which order jobs finished.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SessionDigest {
    pub adapters: BTreeMap<usize, AdapterDigest>,
}

impl SessionDigest {
    pub fn of(report: &SessionReport) -> SessionDigest {
        let mut adapters = BTreeMap::new();
        for o in &report.outcomes {
            for a in &o.report.adapters {
                adapters.insert(a.config.id, AdapterDigest::of_report(a));
            }
        }
        SessionDigest { adapters }
    }

    /// Stable 64-bit fingerprint over every field, in adapter-id order.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(self.adapters.len());
        for (id, a) in &self.adapters {
            h.write_usize(*id);
            h.write_str(&a.task);
            h.write_usize(a.rank);
            h.write_usize(a.batch);
            h.write_u64(a.lr_bits);
            h.write_usize(a.steps);
            for bits in [a.first_loss, a.final_loss, a.base_loss, a.base_acc, a.eval_loss] {
                h.write_u32(bits);
            }
            h.write_u32(a.eval_acc);
            h.write_u64(a.param_hash);
            h.write_usize(a.curve.len());
            for &(s, l) in &a.curve {
                h.write_usize(s);
                h.write_u32(l);
            }
        }
        h.finish()
    }

    /// Human-readable field-level difference report; empty when the two
    /// digests are bit-identical.
    pub fn diff(&self, other: &SessionDigest) -> String {
        let mut lines: Vec<String> = vec![];
        for (id, a) in &self.adapters {
            match other.adapters.get(id) {
                Some(b) => diff_adapter(*id, a, b, &mut lines),
                None => lines.push(format!(
                    "adapter {id} ({}): present in recording, missing from replay",
                    a.task
                )),
            }
        }
        for (id, b) in &other.adapters {
            if !self.adapters.contains_key(id) {
                lines.push(format!(
                    "adapter {id} ({}): present in replay, missing from recording",
                    b.task
                ));
            }
        }
        const CAP: usize = 24;
        if lines.len() > CAP {
            let extra = lines.len() - CAP;
            lines.truncate(CAP);
            lines.push(format!("... and {extra} more difference(s)"));
        }
        lines.join("\n")
    }

    pub fn to_json(&self) -> Json {
        let mut adapters = BTreeMap::new();
        for (id, a) in &self.adapters {
            adapters.insert(id.to_string(), adapter_to_json(a));
        }
        Json::obj(vec![
            ("fingerprint", Json::str(hex64(self.fingerprint()))),
            ("adapters", Json::Obj(adapters)),
        ])
    }

    /// Parse and re-validate the stored fingerprint (catches hand-edited
    /// or truncated trace files before a replay burns compute on them).
    pub fn from_json(v: &Json) -> Result<SessionDigest> {
        let mut adapters = BTreeMap::new();
        let obj = v
            .field("adapters")?
            .as_obj()
            .ok_or_else(|| anyhow!("digest 'adapters': expected object"))?;
        for (id, a) in obj {
            let id: usize =
                id.parse().map_err(|_| anyhow!("digest adapter key '{id}': not an id"))?;
            adapters.insert(id, adapter_from_json(a)?);
        }
        let digest = SessionDigest { adapters };
        let stored = jhex(v, "fingerprint")?;
        if stored != digest.fingerprint() {
            bail!(
                "digest fingerprint mismatch: file says {:016x}, contents hash to {:016x} \
                 (corrupted or hand-edited trace)",
                stored,
                digest.fingerprint()
            );
        }
        Ok(digest)
    }
}

fn diff_adapter(id: usize, a: &AdapterDigest, b: &AdapterDigest, lines: &mut Vec<String>) {
    if a.task != b.task || a.rank != b.rank || a.batch != b.batch || a.lr_bits != b.lr_bits {
        lines.push(format!(
            "adapter {id}: config differs — {}/r{}/bs{}/lr{} vs {}/r{}/bs{}/lr{}",
            a.task,
            a.rank,
            a.batch,
            f64::from_bits(a.lr_bits),
            b.task,
            b.rank,
            b.batch,
            f64::from_bits(b.lr_bits),
        ));
    }
    if a.steps != b.steps {
        lines.push(format!("adapter {id}: steps {} vs {}", a.steps, b.steps));
    }
    let fields = [
        ("first_loss", a.first_loss, b.first_loss),
        ("final_loss", a.final_loss, b.final_loss),
        ("base_loss", a.base_loss, b.base_loss),
        ("base_acc", a.base_acc, b.base_acc),
        ("eval_loss", a.eval_loss, b.eval_loss),
        ("eval_acc", a.eval_acc, b.eval_acc),
    ];
    for (what, x, y) in fields {
        if x != y {
            lines.push(format!(
                "adapter {id}: {what} {:.6} (0x{x:08x}) vs {:.6} (0x{y:08x})",
                f32::from_bits(x),
                f32::from_bits(y),
            ));
        }
    }
    if a.param_hash != b.param_hash {
        lines.push(format!(
            "adapter {id}: param_hash {:016x} vs {:016x}",
            a.param_hash, b.param_hash
        ));
    }
    if a.curve != b.curve {
        let i = a
            .curve
            .iter()
            .zip(&b.curve)
            .position(|(x, y)| x != y)
            .unwrap_or(a.curve.len().min(b.curve.len()));
        lines.push(format!(
            "adapter {id}: loss curve diverges at sample {i} (len {} vs {})",
            a.curve.len(),
            b.curve.len()
        ));
    }
}

// ---------------------------------------------------------------------------
// Trace
// ---------------------------------------------------------------------------

/// One submitted job, as the user submitted it (continuations re-queued by
/// preemption are the session's own business and are *not* recorded — a
/// replay re-derives them).
#[derive(Debug, Clone)]
pub struct TraceJob {
    pub id: usize,
    pub d: usize,
    /// Planned stage-pipeline depth (0 = unplanned, inherit
    /// `PLORA_STAGES`). Provenance like `d`: trajectories are
    /// depth-invariant, so replay at any depth still matches.
    pub s: usize,
    pub mode: ExecMode,
    pub priority: i32,
    pub configs: Vec<LoraConfig>,
}

/// Device-environment knobs in effect at record time. Provenance only:
/// trajectories are bitwise invariant to all of them, so a replay under a
/// different environment still matches — but a *timing* comparison should
/// know what produced the recorded wall clocks.
#[derive(Debug, Clone)]
pub struct TraceEnv {
    pub devices: usize,
    pub threads: usize,
    /// Stage-pipeline depth default (`PLORA_STAGES`) at record time.
    pub stages: usize,
    pub gemm: String,
}

impl TraceEnv {
    pub fn capture() -> TraceEnv {
        let num = |name: &str, default: usize| {
            std::env::var(name)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&v| v >= 1)
                .unwrap_or(default)
        };
        TraceEnv {
            devices: num("PLORA_DEVICES", 1),
            threads: num("PLORA_THREADS", 1),
            stages: num("PLORA_STAGES", 1),
            gemm: std::env::var("PLORA_GEMM").unwrap_or_else(|_| "tiled".into()),
        }
    }
}

/// The early-stopping tuner that drove a recorded sweep. Unlike timings
/// this is a *replay obligation*: an ASHA trace's recorded jobs are the
/// rung-0 submissions only (promotions are tuner decisions, re-derived
/// deterministically), so the replayer must re-run the same tuner to
/// reproduce the digest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TunerSpec {
    pub eta: usize,
    pub rungs: usize,
}

/// A recorded session: settings snapshot, submitted jobs, the ordered
/// event stream, and the deterministic digest of the final report.
#[derive(Debug, Clone)]
pub struct Trace {
    pub schema: u64,
    pub model: String,
    /// Device-pool size of the recording session.
    pub gpus: usize,
    pub policy: Policy,
    pub elastic: bool,
    pub rebucket: bool,
    /// Early-stopping tuner of the recorded sweep (`None` = plain
    /// submit-everything session). `options.budget` is the *full* final
    /// budget; rung budgets are re-derived from it.
    pub tuner: Option<TunerSpec>,
    pub options: TrainOptions,
    pub env: TraceEnv,
    pub jobs: Vec<TraceJob>,
    /// The full event log with wall-clock timestamps (seconds since
    /// session start) — recorded provenance, not a replay obligation.
    pub events: Vec<Event>,
    pub makespan: f64,
    pub digest: SessionDigest,
}

impl Trace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plora_trace", Json::num(self.schema as f64)),
            ("model", Json::str(self.model.as_str())),
            ("gpus", Json::num(self.gpus as f64)),
            ("policy", Json::str(policy_name(self.policy))),
            ("elastic", Json::Bool(self.elastic)),
            ("rebucket", Json::Bool(self.rebucket)),
            (
                "tuner",
                match &self.tuner {
                    Some(t) => Json::obj(vec![
                        ("eta", Json::num(t.eta as f64)),
                        ("rungs", Json::num(t.rungs as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
            ("options", options_to_json(&self.options)),
            (
                "env",
                Json::obj(vec![
                    ("devices", Json::num(self.env.devices as f64)),
                    ("threads", Json::num(self.env.threads as f64)),
                    ("stages", Json::num(self.env.stages as f64)),
                    ("gemm", Json::str(self.env.gemm.as_str())),
                ]),
            ),
            ("jobs", Json::arr(self.jobs.iter().map(job_to_json))),
            ("events", Json::arr(self.events.iter().map(event_to_json))),
            ("makespan", jnum(self.makespan)),
            ("digest", self.digest.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Trace> {
        let schema = jhexnum(v, "plora_trace")?;
        if schema != TRACE_SCHEMA {
            bail!("unsupported trace schema v{schema} (this build reads v{TRACE_SCHEMA})");
        }
        let policy = js(v, "policy")?;
        let policy = Policy::parse(&policy)
            .ok_or_else(|| anyhow!("trace policy '{policy}': unknown"))?;
        let env = v.field("env")?;
        let jobs = jarr(v, "jobs")?.iter().map(job_from_json).collect::<Result<Vec<_>>>()?;
        let events =
            jarr(v, "events")?.iter().map(event_from_json).collect::<Result<Vec<_>>>()?;
        // Absent in pre-tuner recordings: plain session.
        let tuner = match v.field("tuner") {
            Ok(Json::Null) | Err(_) => None,
            Ok(t) => Some(TunerSpec { eta: ju(t, "eta")?, rungs: ju(t, "rungs")? }),
        };
        Ok(Trace {
            schema,
            model: js(v, "model")?,
            gpus: ju(v, "gpus")?,
            policy,
            elastic: jb(v, "elastic")?,
            rebucket: jb(v, "rebucket")?,
            tuner,
            options: options_from_json(v.field("options")?)?,
            env: TraceEnv {
                devices: ju(env, "devices")?,
                threads: ju(env, "threads")?,
                // Absent in pre-pipeline recordings: default depth 1.
                stages: ju(env, "stages").unwrap_or(1),
                gemm: js(env, "gemm")?,
            },
            jobs,
            events,
            makespan: jf(v, "makespan")?,
            digest: SessionDigest::from_json(v.field("digest")?)?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir {}", dir.display()))?;
            }
        }
        let mut out = String::new();
        self.to_json().write(&mut out);
        out.push('\n');
        std::fs::write(path, out).with_context(|| format!("write {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Trace::from_json(&v).with_context(|| format!("parse trace {}", path.display()))
    }

    /// Total adapters across recorded submissions.
    pub fn total_adapters(&self) -> usize {
        self.jobs.iter().map(|j| j.configs.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Recorder
// ---------------------------------------------------------------------------

/// Accumulates a [`Trace`] alongside a running session. Create it once the
/// session's knobs are set, call [`TraceRecorder::submit`] for every job
/// handed to the session, and [`TraceRecorder::finish`] with the drained
/// report.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    trace: Trace,
}

impl TraceRecorder {
    pub fn new(
        model: &str,
        gpus: usize,
        policy: Policy,
        elastic: bool,
        rebucket: bool,
        options: &TrainOptions,
    ) -> TraceRecorder {
        TraceRecorder {
            trace: Trace {
                schema: TRACE_SCHEMA,
                model: model.to_string(),
                gpus,
                policy,
                elastic,
                rebucket,
                tuner: None,
                options: options.clone(),
                env: TraceEnv::capture(),
                jobs: vec![],
                events: vec![],
                makespan: 0.0,
                digest: SessionDigest::default(),
            },
        }
    }

    /// Snapshot a live session's settings (call after `set_policy` /
    /// `set_elastic` / options assignment).
    pub fn for_session(session: &Session) -> TraceRecorder {
        TraceRecorder::new(
            session.model(),
            session.devices(),
            session.policy(),
            session.elastic(),
            session.rebucket,
            &session.options,
        )
    }

    /// Tag the trace as driven by an early-stopping tuner. The recorder's
    /// `options` must then hold the *full* final budget — not a rung's —
    /// so create it via [`TraceRecorder::new`], not
    /// [`TraceRecorder::for_session`] (the live session's options hold
    /// the current rung budget).
    pub fn set_tuner(&mut self, eta: usize, rungs: usize) {
        self.trace.tuner = Some(TunerSpec { eta, rungs });
    }

    pub fn submit(&mut self, job: &PlannedJob, priority: i32) {
        self.trace.jobs.push(TraceJob {
            id: job.id,
            d: job.d,
            s: job.s,
            mode: job.mode,
            priority,
            configs: job.pack.configs.clone(),
        });
    }

    pub fn finish(mut self, report: &SessionReport) -> Trace {
        self.trace.events = report.events.clone();
        self.trace.makespan = report.makespan;
        self.trace.digest = SessionDigest::of(report);
        self.trace
    }
}

// ---------------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------------

/// What a live replay produced, next to what the recording promised.
#[derive(Debug)]
pub struct ReplayOutcome {
    pub report: SessionReport,
    pub digest: SessionDigest,
    pub recorded: SessionDigest,
    /// Field-level mismatch report; empty when bit-identical.
    pub diff: String,
}

impl ReplayOutcome {
    pub fn matches(&self) -> bool {
        self.diff.is_empty()
    }
}

/// Re-execute a trace through a real [`Session`] and compare digests.
///
/// The replay session runs without a checkpoint pool: preemption resume
/// then round-trips in memory instead of through disk, which the session
/// suite pins as bit-identical. Timings, event interleavings and
/// admission hosting may differ from the recording; the digest may not.
pub fn replay(rt: Arc<Runtime>, trace: &Trace) -> Result<ReplayOutcome> {
    if let Some(t) = trace.tuner {
        return replay_tuner(rt, trace, t);
    }
    let monitor = ResourceMonitor::new(&pool::CPU_SIM, trace.gpus);
    let mut session = Session::new(rt, monitor, &trace.model);
    session.options = trace.options.clone();
    session.rebucket = trace.rebucket;
    session.set_policy(trace.policy);
    session.set_elastic(trace.elastic);
    for j in &trace.jobs {
        let job = PlannedJob {
            id: j.id,
            pack: Pack::new(j.configs.clone()),
            d: j.d,
            s: j.s,
            mode: j.mode,
        };
        session.submit_planned_at(job, j.priority)?;
    }
    let report = session.drain()?;
    let digest = SessionDigest::of(&report);
    let diff = trace.digest.diff(&digest);
    Ok(ReplayOutcome { report, digest, recorded: trace.digest.clone(), diff })
}

/// Replay a tuner-driven sweep: the recorded jobs are the rung-0 trials
/// only, so re-run the same [`Asha`] tuner over them. Rung decisions
/// depend only on finalized eval bit patterns ranked with a total order,
/// so the replay makes the same promotions and the digest obligation is
/// unchanged — bit-for-bit.
fn replay_tuner(rt: Arc<Runtime>, trace: &Trace, spec: TunerSpec) -> Result<ReplayOutcome> {
    let configs: Vec<LoraConfig> =
        trace.jobs.iter().flat_map(|j| j.configs.iter().cloned()).collect();
    let opts = SweepOptions {
        budget: trace.options.budget,
        eval_batches: trace.options.eval_batches,
        seed: trace.options.seed,
        gpus: trace.gpus,
        policy: trace.policy,
        elastic: trace.elastic,
    };
    let tuner = Asha { eta: spec.eta, rungs: spec.rungs, ckpt_dir: None };
    let out = tuner.run(&rt, &trace.model, &configs, &opts, None)?;
    let digest = SessionDigest::of(&out.session);
    let diff = trace.digest.diff(&digest);
    Ok(ReplayOutcome { report: out.session, digest, recorded: trace.digest.clone(), diff })
}

/// [`replay`] starting from checkpoint **midpoints** (`plora replay
/// --from-checkpoint <dir>`): adapters with a durable resume payload in
/// `ckpt` — left behind by a preempted or suspended session's drain —
/// continue from their persisted optimizer state and data-stream position
/// instead of step 0. Resumed trajectories are bit-identical to
/// uninterrupted ones, so the digest obligation is unchanged: the
/// recording must still match bit-for-bit. Adapters without a payload
/// replay from step 0 as usual, and everything the replay finishes is
/// checkpointed back into the same pool.
pub fn replay_resume(
    rt: Arc<Runtime>,
    trace: &Trace,
    ckpt: &CheckpointPool,
) -> Result<ReplayOutcome> {
    if trace.tuner.is_some() {
        bail!(
            "tuner-driven traces replay through the tuner itself (`plora replay <path>`); \
             --from-checkpoint applies to plain sessions only"
        );
    }
    let monitor = ResourceMonitor::new(&pool::CPU_SIM, trace.gpus);
    let mut session = Session::new(rt, monitor, &trace.model);
    session.options = trace.options.clone();
    session.rebucket = trace.rebucket;
    session.checkpoints = Some(ckpt.clone());
    session.set_policy(trace.policy);
    session.set_elastic(trace.elastic);
    let mut resumed = 0usize;
    for j in &trace.jobs {
        let mut resume = vec![];
        for c in &j.configs {
            if ckpt.has_resume(&trace.model, c.id) {
                resume.push((c.id, ckpt.load_resume(&trace.model, c.id)?));
            }
        }
        resumed += resume.len();
        let job = PlannedJob {
            id: j.id,
            pack: Pack::new(j.configs.clone()),
            d: j.d,
            s: j.s,
            mode: j.mode,
        };
        session.submit_planned_resume(job, j.priority, resume)?;
    }
    if resumed == 0 {
        eprintln!(
            "plora replay: no resume payloads under {} — replaying from step 0",
            ckpt.dir.display()
        );
    }
    let report = session.drain()?;
    let digest = SessionDigest::of(&report);
    let diff = trace.digest.diff(&digest);
    Ok(ReplayOutcome { report, digest, recorded: trace.digest.clone(), diff })
}

/// Timing-only replay: rebuild the schedule timeline through the
/// simulator's cost model (same queue, priorities, policy and elastic
/// setting) without training anything. The returned
/// [`SimResult::log`] speaks the session's [`Event`] vocabulary, so a
/// recorded timeline and its modeled reconstruction are directly
/// comparable line by line.
pub fn replay_timing(cm: &CostModel, trace: &Trace) -> SimResult {
    let sim = Simulator { cm: cm.clone(), budget: trace.options.budget, gpus: trace.gpus };
    let queue: Vec<PlannedJob> = trace
        .jobs
        .iter()
        .map(|j| PlannedJob {
            id: j.id,
            pack: Pack::new(j.configs.clone()),
            d: j.d,
            s: j.s,
            mode: j.mode,
        })
        .collect();
    let prios: Vec<i32> = trace.jobs.iter().map(|j| j.priority).collect();
    let opts = SimOptions {
        noise: 0.0,
        seed: trace.options.seed,
        policy: trace.policy,
        elastic: trace.elastic,
        grow_devices: false,
        grow_stages: false,
        tuner: trace.tuner.map(|t| (t.eta, t.rungs)),
    };
    sim.run_queue_prio(&queue, &prios, &opts)
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

pub(crate) fn policy_name(p: Policy) -> &'static str {
    match p {
        Policy::Fifo => "fifo",
        Policy::Priority => "priority",
        Policy::PreemptLowest => "preempt",
    }
}

pub(crate) fn mode_name(m: ExecMode) -> &'static str {
    match m {
        ExecMode::Packed => "packed",
        ExecMode::Sequential => "sequential",
    }
}

pub(crate) fn mode_parse(s: &str) -> Result<ExecMode> {
    match s {
        "packed" => Ok(ExecMode::Packed),
        "sequential" => Ok(ExecMode::Sequential),
        other => bail!("unknown exec mode '{other}'"),
    }
}

fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

fn hex32(x: u32) -> String {
    format!("{x:08x}")
}

/// JSON has no non-finite numbers (the writer would emit invalid text for
/// them), so NaN/±inf round-trip as tagged strings.
fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else if x.is_nan() {
        Json::str("nan")
    } else if x > 0.0 {
        Json::str("inf")
    } else {
        Json::str("-inf")
    }
}

fn num_of(v: &Json) -> Option<f64> {
    match v {
        Json::Num(x) => Some(*x),
        Json::Str(s) => match s.as_str() {
            "nan" => Some(f64::NAN),
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            _ => None,
        },
        _ => None,
    }
}

fn jf(v: &Json, k: &str) -> Result<f64> {
    num_of(v.field(k)?).ok_or_else(|| anyhow!("field '{k}': expected number"))
}

fn jf32(v: &Json, k: &str) -> Result<f32> {
    jf(v, k).map(|x| x as f32)
}

fn ju(v: &Json, k: &str) -> Result<usize> {
    jf(v, k).map(|x| x as usize)
}

fn ji(v: &Json, k: &str) -> Result<i32> {
    jf(v, k).map(|x| x as i32)
}

fn ju64(v: &Json, k: &str) -> Result<u64> {
    jf(v, k).map(|x| x as u64)
}

/// Schema numbers are plain JSON integers; named for symmetry with
/// [`jhex`] at the call site.
fn jhexnum(v: &Json, k: &str) -> Result<u64> {
    ju64(v, k)
}

fn js(v: &Json, k: &str) -> Result<String> {
    Ok(v.field(k)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{k}': expected string"))?
        .to_string())
}

fn jb(v: &Json, k: &str) -> Result<bool> {
    v.field(k)?.as_bool().ok_or_else(|| anyhow!("field '{k}': expected bool"))
}

fn jarr<'a>(v: &'a Json, k: &str) -> Result<&'a [Json]> {
    v.field(k)?.as_arr().ok_or_else(|| anyhow!("field '{k}': expected array"))
}

fn jvec_usize(v: &Json, k: &str) -> Result<Vec<usize>> {
    jarr(v, k)?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("field '{k}': expected integers")))
        .collect()
}

fn jtriple(v: &Json, k: &str) -> Result<(usize, usize, usize)> {
    let a = jvec_usize(v, k)?;
    if a.len() != 3 {
        bail!("field '{k}': expected a 3-tuple, got {} entries", a.len());
    }
    Ok((a[0], a[1], a[2]))
}

/// 64-bit values (hashes, f64 bit patterns) don't fit f64 exactly, so they
/// travel as 16-digit hex strings.
fn jhex(v: &Json, k: &str) -> Result<u64> {
    let s = js(v, k)?;
    u64::from_str_radix(&s, 16).map_err(|_| anyhow!("field '{k}': bad hex '{s}'"))
}

fn jhex32(v: &Json, k: &str) -> Result<u32> {
    let s = js(v, k)?;
    u32::from_str_radix(&s, 16).map_err(|_| anyhow!("field '{k}': bad hex '{s}'"))
}

pub(crate) fn options_to_json(o: &TrainOptions) -> Json {
    Json::obj(vec![
        ("dataset", Json::num(o.budget.dataset as f64)),
        ("epochs", Json::num(o.budget.epochs as f64)),
        ("eval_batches", Json::num(o.eval_batches as f64)),
        ("seed", Json::num(o.seed as f64)),
        ("log_every", Json::num(o.log_every as f64)),
    ])
}

pub(crate) fn options_from_json(v: &Json) -> Result<TrainOptions> {
    Ok(TrainOptions {
        budget: TrainBudget { dataset: ju(v, "dataset")?, epochs: ju(v, "epochs")? },
        eval_batches: ju(v, "eval_batches")?,
        seed: ju64(v, "seed")?,
        log_every: ju(v, "log_every")?,
    })
}

pub(crate) fn config_to_json(c: &LoraConfig) -> Json {
    Json::obj(vec![
        ("id", Json::num(c.id as f64)),
        ("lr", jnum(c.lr)),
        ("batch", Json::num(c.batch as f64)),
        ("rank", Json::num(c.rank as f64)),
        ("alpha_ratio", jnum(c.alpha_ratio)),
        ("task", Json::str(c.task.as_str())),
    ])
}

pub(crate) fn config_from_json(v: &Json) -> Result<LoraConfig> {
    Ok(LoraConfig {
        id: ju(v, "id")?,
        lr: jf(v, "lr")?,
        batch: ju(v, "batch")?,
        rank: ju(v, "rank")?,
        alpha_ratio: jf(v, "alpha_ratio")?,
        task: js(v, "task")?,
    })
}

fn job_to_json(j: &TraceJob) -> Json {
    Json::obj(vec![
        ("id", Json::num(j.id as f64)),
        ("d", Json::num(j.d as f64)),
        ("s", Json::num(j.s as f64)),
        ("mode", Json::str(mode_name(j.mode))),
        ("priority", Json::num(j.priority as f64)),
        ("adapters", Json::arr(j.configs.iter().map(config_to_json))),
    ])
}

fn job_from_json(v: &Json) -> Result<TraceJob> {
    Ok(TraceJob {
        id: ju(v, "id")?,
        d: ju(v, "d")?,
        // Absent in pre-pipeline recordings: unplanned depth.
        s: ju(v, "s").unwrap_or(0),
        mode: mode_parse(&js(v, "mode")?)?,
        priority: ji(v, "priority")?,
        configs: jarr(v, "adapters")?
            .iter()
            .map(config_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn adapter_to_json(d: &AdapterDigest) -> Json {
    Json::obj(vec![
        ("task", Json::str(d.task.as_str())),
        ("rank", Json::num(d.rank as f64)),
        ("batch", Json::num(d.batch as f64)),
        ("lr_bits", Json::str(hex64(d.lr_bits))),
        ("steps", Json::num(d.steps as f64)),
        ("first_loss", Json::str(hex32(d.first_loss))),
        ("final_loss", Json::str(hex32(d.final_loss))),
        ("base_loss", Json::str(hex32(d.base_loss))),
        ("base_acc", Json::str(hex32(d.base_acc))),
        ("eval_loss", Json::str(hex32(d.eval_loss))),
        ("eval_acc", Json::str(hex32(d.eval_acc))),
        ("param_hash", Json::str(hex64(d.param_hash))),
        (
            "curve",
            Json::arr(
                d.curve
                    .iter()
                    .map(|&(s, l)| Json::arr([Json::num(s as f64), Json::str(hex32(l))])),
            ),
        ),
    ])
}

fn adapter_from_json(v: &Json) -> Result<AdapterDigest> {
    let curve = jarr(v, "curve")?
        .iter()
        .map(|p| -> Result<(usize, u32)> {
            let p = p.as_arr().ok_or_else(|| anyhow!("curve entry: expected [step, hex]"))?;
            if p.len() != 2 {
                bail!("curve entry: expected [step, hex]");
            }
            let s = p[0].as_usize().ok_or_else(|| anyhow!("curve step: expected integer"))?;
            let l = p[1].as_str().ok_or_else(|| anyhow!("curve loss: expected hex string"))?;
            Ok((s, u32::from_str_radix(l, 16).map_err(|_| anyhow!("curve loss: bad hex"))?))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(AdapterDigest {
        task: js(v, "task")?,
        rank: ju(v, "rank")?,
        batch: ju(v, "batch")?,
        lr_bits: jhex(v, "lr_bits")?,
        steps: ju(v, "steps")?,
        first_loss: jhex32(v, "first_loss")?,
        final_loss: jhex32(v, "final_loss")?,
        base_loss: jhex32(v, "base_loss")?,
        base_acc: jhex32(v, "base_acc")?,
        eval_loss: jhex32(v, "eval_loss")?,
        eval_acc: jhex32(v, "eval_acc")?,
        param_hash: jhex(v, "param_hash")?,
        curve,
    })
}

/// One session [`Event`] as a tagged JSON object (`"ev"` discriminant).
pub fn event_to_json(ev: &Event) -> Json {
    let unum = |x: usize| Json::num(x as f64);
    let uvec = |xs: &[usize]| Json::arr(xs.iter().map(|&x| unum(x)));
    let triple =
        |t: (usize, usize, usize)| Json::arr([unum(t.0), unum(t.1), unum(t.2)]);
    match ev {
        Event::JobStarted { job, n_adapters, devices, at } => Json::obj(vec![
            ("ev", Json::str("job_started")),
            ("job", unum(*job)),
            ("n_adapters", unum(*n_adapters)),
            ("devices", uvec(devices)),
            ("at", jnum(*at)),
        ]),
        Event::AdapterFinished { job, adapter, task, steps, eval_loss, eval_acc, at } => {
            Json::obj(vec![
                ("ev", Json::str("adapter_finished")),
                ("job", unum(*job)),
                ("adapter", unum(*adapter)),
                ("task", Json::str(task.as_str())),
                ("steps", unum(*steps)),
                ("eval_loss", jnum(*eval_loss as f64)),
                ("eval_acc", jnum(*eval_acc as f64)),
                ("at", jnum(*at)),
            ])
        }
        Event::AdapterAdmitted { job, adapter, task, from_job, at } => Json::obj(vec![
            ("ev", Json::str("adapter_admitted")),
            ("job", unum(*job)),
            ("adapter", unum(*adapter)),
            ("task", Json::str(task.as_str())),
            ("from_job", unum(*from_job)),
            ("at", jnum(*at)),
        ]),
        Event::Rebucketed { job, from, to, survivors, at } => Json::obj(vec![
            ("ev", Json::str("rebucketed")),
            ("job", unum(*job)),
            ("from", triple(*from)),
            ("to", triple(*to)),
            ("survivors", uvec(survivors)),
            ("at", jnum(*at)),
        ]),
        Event::Preempted { job, adapters, at } => Json::obj(vec![
            ("ev", Json::str("preempted")),
            ("job", unum(*job)),
            ("adapters", uvec(adapters)),
            ("at", jnum(*at)),
        ]),
        Event::DeviceRetarget { job, from, to, at } => Json::obj(vec![
            ("ev", Json::str("device_retarget")),
            ("job", unum(*job)),
            ("from", unum(*from)),
            ("to", unum(*to)),
            ("at", jnum(*at)),
        ]),
        Event::StageRetarget { job, from, to, at } => Json::obj(vec![
            ("ev", Json::str("stage_retarget")),
            ("job", unum(*job)),
            ("from", unum(*from)),
            ("to", unum(*to)),
            ("at", jnum(*at)),
        ]),
        Event::JobFinished { job, adapters, wall, at } => Json::obj(vec![
            ("ev", Json::str("job_finished")),
            ("job", unum(*job)),
            ("adapters", unum(*adapters)),
            ("wall", jnum(*wall)),
            ("at", jnum(*at)),
        ]),
        Event::JobFailed { job, error, at } => Json::obj(vec![
            ("ev", Json::str("job_failed")),
            ("job", unum(*job)),
            ("error", Json::str(error.as_str())),
            ("at", jnum(*at)),
        ]),
        Event::TrialPromoted { rung, adapter, at } => Json::obj(vec![
            ("ev", Json::str("trial_promoted")),
            ("rung", unum(*rung)),
            ("adapter", unum(*adapter)),
            ("at", jnum(*at)),
        ]),
        Event::RungDecision { rung, task, survivors, demoted, at } => Json::obj(vec![
            ("ev", Json::str("rung_decision")),
            ("rung", unum(*rung)),
            ("task", Json::str(task.as_str())),
            ("survivors", uvec(survivors)),
            ("demoted", uvec(demoted)),
            ("at", jnum(*at)),
        ]),
        Event::CalibUpdated { fit, samples, switch_cost, dp_fit, device_switch_cost, at } => {
            let dp = match dp_fit {
                Some((a, b)) => Json::arr([jnum(*a), jnum(*b)]),
                None => Json::Null,
            };
            Json::obj(vec![
                ("ev", Json::str("calib_updated")),
                ("fit", Json::arr([jnum(fit.0), jnum(fit.1), jnum(fit.2)])),
                ("samples", unum(*samples)),
                ("switch_cost", jnum(*switch_cost)),
                ("dp_fit", dp),
                ("device_switch_cost", jnum(*device_switch_cost)),
                ("at", jnum(*at)),
            ])
        }
    }
}

pub fn event_from_json(v: &Json) -> Result<Event> {
    let kind = js(v, "ev")?;
    Ok(match kind.as_str() {
        "job_started" => Event::JobStarted {
            job: ju(v, "job")?,
            n_adapters: ju(v, "n_adapters")?,
            devices: jvec_usize(v, "devices")?,
            at: jf(v, "at")?,
        },
        "adapter_finished" => Event::AdapterFinished {
            job: ju(v, "job")?,
            adapter: ju(v, "adapter")?,
            task: js(v, "task")?,
            steps: ju(v, "steps")?,
            eval_loss: jf32(v, "eval_loss")?,
            eval_acc: jf32(v, "eval_acc")?,
            at: jf(v, "at")?,
        },
        "adapter_admitted" => Event::AdapterAdmitted {
            job: ju(v, "job")?,
            adapter: ju(v, "adapter")?,
            task: js(v, "task")?,
            from_job: ju(v, "from_job")?,
            at: jf(v, "at")?,
        },
        "rebucketed" => Event::Rebucketed {
            job: ju(v, "job")?,
            from: jtriple(v, "from")?,
            to: jtriple(v, "to")?,
            survivors: jvec_usize(v, "survivors")?,
            at: jf(v, "at")?,
        },
        "preempted" => Event::Preempted {
            job: ju(v, "job")?,
            adapters: jvec_usize(v, "adapters")?,
            at: jf(v, "at")?,
        },
        "device_retarget" => Event::DeviceRetarget {
            job: ju(v, "job")?,
            from: ju(v, "from")?,
            to: ju(v, "to")?,
            at: jf(v, "at")?,
        },
        "stage_retarget" => Event::StageRetarget {
            job: ju(v, "job")?,
            from: ju(v, "from")?,
            to: ju(v, "to")?,
            at: jf(v, "at")?,
        },
        "job_finished" => Event::JobFinished {
            job: ju(v, "job")?,
            adapters: ju(v, "adapters")?,
            wall: jf(v, "wall")?,
            at: jf(v, "at")?,
        },
        "job_failed" => Event::JobFailed {
            job: ju(v, "job")?,
            error: js(v, "error")?,
            at: jf(v, "at")?,
        },
        "trial_promoted" => Event::TrialPromoted {
            rung: ju(v, "rung")?,
            adapter: ju(v, "adapter")?,
            at: jf(v, "at")?,
        },
        "rung_decision" => Event::RungDecision {
            rung: ju(v, "rung")?,
            task: js(v, "task")?,
            survivors: jvec_usize(v, "survivors")?,
            demoted: jvec_usize(v, "demoted")?,
            at: jf(v, "at")?,
        },
        "calib_updated" => {
            let fit = jarr(v, "fit")?;
            if fit.len() != 3 {
                bail!("calib_updated fit: expected 3 numbers");
            }
            let fnum = |x: &Json| {
                num_of(x).ok_or_else(|| anyhow!("calib_updated fit: expected numbers"))
            };
            let dp_fit = match v.field("dp_fit")? {
                Json::Null => None,
                Json::Arr(a) if a.len() == 2 => Some((fnum(&a[0])?, fnum(&a[1])?)),
                _ => bail!("calib_updated dp_fit: expected null or [a, b]"),
            };
            Event::CalibUpdated {
                fit: (fnum(&fit[0])?, fnum(&fit[1])?, fnum(&fit[2])?),
                samples: ju(v, "samples")?,
                switch_cost: jf(v, "switch_cost")?,
                dp_fit,
                device_switch_cost: jf(v, "device_switch_cost")?,
                at: jf(v, "at")?,
            }
        }
        other => bail!("unknown event kind '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn every_event() -> Vec<Event> {
        vec![
            Event::JobStarted { job: 0, n_adapters: 2, devices: vec![0, 1], at: 0.5 },
            Event::AdapterFinished {
                job: 0,
                adapter: 3,
                task: "modadd".into(),
                steps: 16,
                eval_loss: 0.25,
                eval_acc: f32::NAN,
                at: 1.5,
            },
            Event::AdapterAdmitted {
                job: 0,
                adapter: 4,
                task: "copy".into(),
                from_job: 2,
                at: 1.6,
            },
            Event::Rebucketed {
                job: 0,
                from: (2, 8, 2),
                to: (1, 8, 1),
                survivors: vec![3],
                at: 1.7,
            },
            Event::Preempted { job: 1, adapters: vec![5, 6], at: 2.0 },
            Event::DeviceRetarget { job: 0, from: 1, to: 2, at: 2.1 },
            Event::StageRetarget { job: 0, from: 1, to: 2, at: 2.2 },
            Event::JobFinished { job: 0, adapters: 2, wall: 3.25, at: 3.75 },
            Event::JobFailed { job: 9, error: "boom \"quoted\"".into(), at: 4.0 },
            Event::TrialPromoted { rung: 0, adapter: 3, at: 4.1 },
            Event::RungDecision {
                rung: 0,
                task: "modadd".into(),
                survivors: vec![3],
                demoted: vec![5, 6],
                at: 4.2,
            },
            Event::CalibUpdated {
                fit: (0.1, 2e-6, 3e-3),
                samples: 40,
                switch_cost: 0.02,
                dp_fit: Some((0.01, 0.04)),
                device_switch_cost: 0.0,
                at: 4.5,
            },
            Event::CalibUpdated {
                fit: (0.0, 0.0, 0.0),
                samples: 0,
                switch_cost: 0.0,
                dp_fit: None,
                device_switch_cost: 0.0,
                at: 5.0,
            },
        ]
    }

    /// Every event variant survives JSON round-tripping bit-exactly
    /// (NaN included — it travels as a tagged string).
    #[test]
    fn event_json_roundtrip() {
        for ev in every_event() {
            let j = event_to_json(&ev);
            let text = j.to_string();
            let back = event_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(
                event_to_json(&back).to_string(),
                text,
                "event did not round-trip: {ev:?}"
            );
        }
    }

    fn digest_fixture() -> SessionDigest {
        let mut adapters = BTreeMap::new();
        adapters.insert(
            7,
            AdapterDigest {
                task: "parity".into(),
                rank: 8,
                batch: 2,
                lr_bits: 2e-3f64.to_bits(),
                steps: 12,
                first_loss: 1.5f32.to_bits(),
                final_loss: 0.25f32.to_bits(),
                base_loss: 1.75f32.to_bits(),
                base_acc: 0.5f32.to_bits(),
                eval_loss: 0.3f32.to_bits(),
                eval_acc: 0.875f32.to_bits(),
                param_hash: 0xdead_beef_cafe_f00d,
                curve: vec![(0, 1.5f32.to_bits()), (8, 0.5f32.to_bits())],
            },
        );
        SessionDigest { adapters }
    }

    #[test]
    fn digest_json_roundtrip_and_tamper_detection() {
        let d = digest_fixture();
        let j = d.to_json();
        let back = SessionDigest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.fingerprint(), d.fingerprint());

        // Flip one loss bit: the stored fingerprint no longer matches.
        let text = j.to_string().replace(&hex32(0.3f32.to_bits()), &hex32(0.31f32.to_bits()));
        let err = SessionDigest::from_json(&Json::parse(&text).unwrap());
        assert!(err.is_err(), "tampered digest must fail fingerprint validation");
    }

    #[test]
    fn digest_diff_is_readable_and_empty_on_match() {
        let a = digest_fixture();
        assert_eq!(a.diff(&a), "");
        let mut b = a.clone();
        let ad = b.adapters.get_mut(&7).unwrap();
        ad.eval_loss = 0.9f32.to_bits();
        ad.param_hash = 1;
        let diff = a.diff(&b);
        assert!(diff.contains("adapter 7"), "diff names the adapter: {diff}");
        assert!(diff.contains("eval_loss"), "diff names the field: {diff}");
        assert!(diff.contains("param_hash"), "diff covers param hashes: {diff}");
        let mut c = a.clone();
        c.adapters.remove(&7);
        assert!(a.diff(&c).contains("missing from replay"));
    }

    #[test]
    fn policy_and_mode_names_roundtrip() {
        for p in [Policy::Fifo, Policy::Priority, Policy::PreemptLowest] {
            assert_eq!(Policy::parse(policy_name(p)), Some(p));
        }
        for m in [ExecMode::Packed, ExecMode::Sequential] {
            assert_eq!(mode_parse(mode_name(m)).unwrap(), m);
        }
    }
}
