//! The **perf-trajectory regression harness** (DESIGN.md §12).
//!
//! Benches emit `BENCH_*.json`; a *snapshot* committed under
//! `bench/history/` says which of those metrics are promises and how they
//! are allowed to move. `plora perf-budget --current <bench json>
//! --baseline <snapshot>` evaluates the promises; CI runs it on every PR.
//!
//! A snapshot has two kinds of gate, because two kinds of number come out
//! of a bench:
//!
//! - **`budget`** — machine-independent metrics (speedup ratios, elastic
//!   vs FIFO makespan ratios, admission counts) with a hard `min` or
//!   `max` bound. These mean the same thing on any hardware, so they are
//!   always enforced, tolerance-free.
//! - **`times`** — absolute wall-clock metrics (step seconds, makespans).
//!   These are only comparable against a recorded run *from the same kind
//!   of machine*, so they are enforced against the snapshot's `record`
//!   (the last accepted bench output) with a relative `tolerance`, and
//!   reported informationally when `record` is `null` (a fresh snapshot
//!   that has never been updated on CI hardware).
//!
//! Intentional regressions bypass the gate explicitly: CI exports
//! `PLORA_PERF_OVERRIDE=1` when the PR carries the `perf-budget-override`
//! label, which turns failures into warnings (the checks still print).

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Snapshot schema version (also the version benches stamp into their
/// `BENCH_*.json` output as `"schema"`).
pub const SNAPSHOT_SCHEMA: u64 = 1;

/// How one metric is gated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckKind {
    /// Budget gate: `current <= bound`.
    Max,
    /// Budget gate: `current >= bound`.
    Min,
    /// Time gate: `current <= record * (1 + tolerance)`.
    Time,
    /// Informational only — no record to compare against.
    Ref,
}

/// One evaluated gate.
#[derive(Debug, Clone)]
pub struct Check {
    pub metric: String,
    pub current: f64,
    /// The reference value (budget bound or recorded time); NaN for
    /// [`CheckKind::Ref`].
    pub baseline: f64,
    /// The enforced bound after tolerance; NaN for [`CheckKind::Ref`].
    pub bound: f64,
    pub kind: CheckKind,
    pub ok: bool,
}

impl Check {
    /// One aligned report line, e.g.
    /// `FAIL skew_elastic_vs_fifo  0.9812 > max 0.97`.
    pub fn render(&self) -> String {
        let status = if self.ok { "  ok" } else { "FAIL" };
        match self.kind {
            CheckKind::Max => format!(
                "{status} {:<28} {:.4} {} max {:.4}",
                self.metric,
                self.current,
                if self.ok { "<=" } else { "> " },
                self.bound
            ),
            CheckKind::Min => format!(
                "{status} {:<28} {:.4} {} min {:.4}",
                self.metric,
                self.current,
                if self.ok { ">=" } else { "< " },
                self.bound
            ),
            CheckKind::Time => format!(
                "{status} {:<28} {:.4}s vs recorded {:.4}s (bound {:.4}s)",
                self.metric, self.current, self.baseline, self.bound
            ),
            CheckKind::Ref => format!(
                " ref {:<28} {:.4}s (no recorded baseline)",
                self.metric, self.current
            ),
        }
    }
}

fn metric(v: &Json, name: &str) -> Result<f64> {
    v.get(name)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("bench output is missing metric '{name}'"))
}

/// Evaluate a current bench output against a committed snapshot.
///
/// Fails (returns `Err`) on *structural* problems — schema or bench-name
/// mismatch, a gated metric missing from the current output — because
/// those mean the harness itself broke, not that perf moved. Perf
/// verdicts live in the returned [`Check`]s' `ok` flags.
pub fn perf_budget(current: &Json, baseline: &Json, tolerance: f64) -> Result<Vec<Check>> {
    if !(0.0..10.0).contains(&tolerance) {
        bail!("tolerance {tolerance} out of range (expected 0..10)");
    }
    let schema = baseline.field("schema")?.as_u64().unwrap_or(0);
    if schema != SNAPSHOT_SCHEMA {
        bail!("snapshot schema v{schema}, this build reads v{SNAPSHOT_SCHEMA}");
    }
    let cur_schema = current.field("schema")?.as_u64().unwrap_or(0);
    if cur_schema != SNAPSHOT_SCHEMA {
        bail!(
            "bench output schema v{cur_schema}, this build reads v{SNAPSHOT_SCHEMA} \
             (re-run the bench from this checkout)"
        );
    }
    let want = baseline.field("bench")?.as_str().unwrap_or("").to_string();
    let got = current.field("bench")?.as_str().unwrap_or("").to_string();
    if want != got {
        bail!("snapshot is for bench '{want}' but the output is from '{got}'");
    }

    let mut checks = vec![];

    let budget = baseline
        .field("budget")?
        .as_obj()
        .ok_or_else(|| anyhow!("snapshot 'budget': expected object"))?;
    for (name, gate) in budget {
        let cur = metric(current, name)?;
        if let Some(bound) = gate.get("max").and_then(Json::as_f64) {
            checks.push(Check {
                metric: name.clone(),
                current: cur,
                baseline: bound,
                bound,
                kind: CheckKind::Max,
                ok: cur <= bound,
            });
        } else if let Some(bound) = gate.get("min").and_then(Json::as_f64) {
            checks.push(Check {
                metric: name.clone(),
                current: cur,
                baseline: bound,
                bound,
                kind: CheckKind::Min,
                ok: cur >= bound,
            });
        } else {
            bail!("snapshot budget '{name}': expected a 'max' or 'min' bound");
        }
    }

    let times = baseline
        .field("times")?
        .as_arr()
        .ok_or_else(|| anyhow!("snapshot 'times': expected array of metric names"))?;
    let record = baseline.field("record")?;
    for name in times {
        let name =
            name.as_str().ok_or_else(|| anyhow!("snapshot 'times': expected strings"))?;
        let cur = metric(current, name)?;
        match record.get(name).and_then(Json::as_f64) {
            Some(base) => {
                let bound = base * (1.0 + tolerance);
                checks.push(Check {
                    metric: name.to_string(),
                    current: cur,
                    baseline: base,
                    bound,
                    kind: CheckKind::Time,
                    ok: cur <= bound,
                });
            }
            None => checks.push(Check {
                metric: name.to_string(),
                current: cur,
                baseline: f64::NAN,
                bound: f64::NAN,
                kind: CheckKind::Ref,
                ok: true,
            }),
        }
    }

    Ok(checks)
}

/// A new snapshot with the current bench output installed as `record`
/// (budget bounds and the gated-metric list are kept verbatim). This is
/// what `--update-baseline` writes after an accepted perf change.
pub fn update_snapshot(baseline: &Json, current: &Json) -> Json {
    let mut out = match baseline {
        Json::Obj(m) => m.clone(),
        _ => Default::default(),
    };
    out.insert("record".to_string(), current.clone());
    Json::Obj(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot(record: Json) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("session")),
            (
                "budget",
                Json::obj(vec![
                    ("ratio", Json::obj(vec![("max", Json::num(0.97))])),
                    ("admissions", Json::obj(vec![("min", Json::num(1.0))])),
                ]),
            ),
            ("times", Json::arr([Json::str("makespan_s")])),
            ("record", record),
        ])
    }

    fn bench(ratio: f64, admissions: f64, makespan: f64) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("session")),
            ("ratio", Json::num(ratio)),
            ("admissions", Json::num(admissions)),
            ("makespan_s", Json::num(makespan)),
        ])
    }

    #[test]
    fn budget_gates_enforce_min_and_max() {
        let snap = snapshot(Json::Null);
        let good = perf_budget(&bench(0.90, 3.0, 12.0), &snap, 0.25).unwrap();
        assert!(good.iter().all(|c| c.ok), "{good:?}");
        // Ratio over its max and admissions under its min both fail.
        let bad = perf_budget(&bench(0.99, 0.0, 12.0), &snap, 0.25).unwrap();
        let failed: Vec<&str> =
            bad.iter().filter(|c| !c.ok).map(|c| c.metric.as_str()).collect();
        assert_eq!(failed, ["admissions", "ratio"]);
    }

    #[test]
    fn times_informational_without_record_gated_with_one() {
        let fresh = snapshot(Json::Null);
        let checks = perf_budget(&bench(0.9, 2.0, 99.0), &fresh, 0.25).unwrap();
        let t = checks.iter().find(|c| c.metric == "makespan_s").unwrap();
        assert_eq!(t.kind, CheckKind::Ref);
        assert!(t.ok, "no record: absolute time is informational");

        let recorded = snapshot(bench(0.9, 2.0, 10.0));
        let ok = perf_budget(&bench(0.9, 2.0, 12.0), &recorded, 0.25).unwrap();
        assert!(ok.iter().all(|c| c.ok), "12.0 <= 10.0 * 1.25");
        let slow = perf_budget(&bench(0.9, 2.0, 13.0), &recorded, 0.25).unwrap();
        let t = slow.iter().find(|c| c.metric == "makespan_s").unwrap();
        assert_eq!(t.kind, CheckKind::Time);
        assert!(!t.ok, "13.0 > 10.0 * 1.25 must fail");
        assert!(t.render().starts_with("FAIL"), "{}", t.render());
    }

    #[test]
    fn structural_problems_are_errors_not_failures() {
        let snap = snapshot(Json::Null);
        // Missing gated metric.
        let partial = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("session")),
            ("ratio", Json::num(0.9)),
        ]);
        assert!(perf_budget(&partial, &snap, 0.25).is_err());
        // Wrong bench.
        let other = {
            let mut b = bench(0.9, 2.0, 10.0);
            if let Json::Obj(m) = &mut b {
                m.insert("bench".into(), Json::str("train_step"));
            }
            b
        };
        assert!(perf_budget(&other, &snap, 0.25).is_err());
        // Wrong schema.
        let old = {
            let mut b = bench(0.9, 2.0, 10.0);
            if let Json::Obj(m) = &mut b {
                m.insert("schema".into(), Json::num(0.0));
            }
            b
        };
        assert!(perf_budget(&old, &snap, 0.25).is_err());
    }

    #[test]
    fn update_baseline_installs_record_and_keeps_gates() {
        let snap = snapshot(Json::Null);
        let cur = bench(0.9, 2.0, 10.0);
        let updated = update_snapshot(&snap, &cur);
        assert_eq!(updated.get("record"), Some(&cur));
        assert_eq!(updated.get("budget"), snap.get("budget"));
        // The updated snapshot now gates absolute times.
        let checks = perf_budget(&bench(0.9, 2.0, 20.0), &updated, 0.25).unwrap();
        let t = checks.iter().find(|c| c.metric == "makespan_s").unwrap();
        assert_eq!(t.kind, CheckKind::Time);
        assert!(!t.ok);
    }
}
