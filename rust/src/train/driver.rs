//! Live packed fine-tuning driver: run one job (a pack of LoRA configs
//! sharing a frozen base model) against the AOT train/eval artifacts.
//!
//! This is the L3 side of the paper's Figure 2 workflow — each adapter
//! receives its own task batch; the base weights are shared; per-adapter
//! alpha, learning rate, rank mask and loss mask carry the heterogeneity.
//!
//! Three properties make the driver orchestration-friendly (the `session`
//! subsystem builds on all of them — DESIGN.md §10 "Elastic sessions"):
//!
//! - **Per-adapter streams and clocks**: an adapter's A-init, train
//!   batches, eval batches *and AdamW step counter* come from its own
//!   `(seed, id)`-keyed state, so its whole trajectory is bit-identical
//!   whether it runs solo, packed from the start, admitted mid-job, or
//!   preempted and resumed (§3.2 "identical to single-adapter
//!   fine-tuning").
//! - **Elastic boundaries**: training advances between adapter-completion
//!   boundaries; at each boundary finished adapters are evaluated and
//!   reported, the session may **inject queued joiners**
//!   ([`ElasticCtl::offer`]), and the pack is re-targeted onto the
//!   cheapest admitting bucket — growing *or* shrinking — only when the
//!   modeled phase-time saving beats the calibrated switch cost
//!   ([`crate::planner::rebalance::retarget_bucket`]).
//! - **Preemption**: a dispatcher-set flag ([`ElasticCtl::preempt`])
//!   stops the job at the next step; every unfinished member is
//!   checkpointed at true rank (params + moments + its own `t`) into
//!   [`MemberResume`]s the session re-queues, and a later run restores
//!   them bit-identically via [`ElasticCtl::resume`].
//! - **Executed device parallelism** (DESIGN.md §11): the job runs on
//!   its real [`Allocation`] — [`run_pack_phased`] splits the pack's
//!   rows across the allocated devices through [`ShardedState`] with a
//!   fixed-order deterministic gradient reduction, so trajectories are
//!   bitwise identical at any device count; boundary device offers
//!   ([`ElasticCtl::devices`]) may grow the shard set onto freed devices
//!   mid-job, calibrated by [`ElasticCtl::device_cost`] /
//!   [`ElasticCtl::dp_stat`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cluster::Allocation;
use crate::config::LoraConfig;
use crate::costmodel::{DpStat, Pack, SwitchCost, TrainBudget};
use crate::planner::rebalance::retarget_bucket;
use crate::runtime::manifest::TokenLayout;
use crate::runtime::state::{JoinSource, MemberState};
use crate::runtime::{Executable, HostTensor, ModelInfo, Runtime, ShardedState, TrainState};
use crate::train::tasks::{self, Sample, SampleBuf};
use crate::util::rng::Rng;

/// Default device count for standalone (pool-less) runs: the
/// `PLORA_DEVICES` env knob, clamped to ≥ 1. Session jobs get their real
/// [`Allocation`] from the Resource Monitor instead; this knob is how the
/// CI suite runs every solo baseline sharded (`PLORA_DEVICES=2`) and
/// still demands bitwise-identical results.
pub fn devices_default() -> usize {
    std::env::var("PLORA_DEVICES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&d| d >= 1)
        .unwrap_or(1)
}

/// Default pipeline depth: the `PLORA_STAGES` env knob, clamped to ≥ 1.
/// At 1 (the default) execution is layer-monolithic and every existing
/// path is unchanged; at `s > 1` each shard streams its rows through `s`
/// layer-stage workers ([`crate::runtime::pipeline::PipelinedExec`]) —
/// bitwise identically, which is how the CI pipelined leg
/// (`PLORA_STAGES=2`) re-checks the golden digests.
pub fn stages_default() -> usize {
    std::env::var("PLORA_STAGES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}

/// Options for one live job.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainOptions {
    pub budget: TrainBudget,
    /// Held-out batches for eval (before and after fine-tuning).
    pub eval_batches: usize,
    pub seed: u64,
    /// Record the loss curve every `log_every` steps (0 = final only).
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { budget: TrainBudget::default(), eval_batches: 4, seed: 17, log_every: 8 }
    }
}

/// Per-adapter outcome of a job.
#[derive(Debug, Clone)]
pub struct AdapterReport {
    pub config: LoraConfig,
    /// Steps this adapter actually trained (its own budget).
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Eval metrics before any update (base-model quality: B=0 ⇒ Δ=0).
    pub base_loss: f32,
    pub base_acc: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
    /// FNV-1a fingerprint of the adapter's final LoRA parameters (bit
    /// patterns, true rank) — the trace digest's proof that replayed
    /// weights, not just replayed metrics, are bit-identical.
    pub param_hash: u64,
    /// `(step, train_loss)` samples.
    pub curve: Vec<(usize, f32)>,
}

/// Outcome of one packed fine-tuning job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub artifact: String,
    /// Initial bucket shape executed (≥ requested pack shape; elastic
    /// re-bucketing may grow or shrink it mid-job).
    pub bucket_n: usize,
    pub bucket_r: usize,
    pub bucket_bs: usize,
    /// Steps executed by this run (a preempted segment executes fewer
    /// than the pack's budget; the continuation runs the rest).
    pub steps: usize,
    pub wall_secs: f64,
    /// Mean step wall time (excludes compile).
    pub step_secs: f64,
    pub compile_secs: f64,
    /// Adapters that *finished* in this run (admitted joiners included;
    /// preempted members are not here — they return as [`MemberResume`]).
    pub adapters: Vec<AdapterReport>,
    /// `(real_tokens, alive_adapters, secs)` per step — feeds
    /// `Calib::fit_live` (§4 "profiling data from the first iterations").
    pub profile: Vec<(f64, f64, f64)>,
    /// Padded rows (bucket `n × bs`) summed over executed steps — the
    /// deterministic work proxy that re-bucketing shrinks and admission
    /// fills with real work.
    pub padded_rows: usize,
    /// Bucket switches performed at adapter-completion boundaries.
    pub rebuckets: usize,
    /// Queued adapters admitted into this pack at boundaries.
    pub admitted: usize,
    /// Largest device count this run executed on (the allocation's size,
    /// grown by boundary device retargets).
    pub d: usize,
    /// Device retargets performed at boundaries.
    pub dretargets: usize,
    /// Largest effective pipeline depth this run executed with (1 =
    /// layer-monolithic; grown/shrunk by boundary stage retargets).
    pub s: usize,
    /// Pipeline-stage retargets performed at boundaries.
    pub sretargets: usize,
}

impl JobReport {
    /// Rank-units per second — the DTM objective measured live.
    pub fn rank_throughput(&self) -> f64 {
        let r: usize = self.adapters.iter().map(|a| a.config.rank).sum();
        r as f64 / self.wall_secs.max(1e-9)
    }
}

/// One adapter's resumable training state: what a preemption checkpoint
/// carries out of a job and what [`ElasticCtl::resume`] /
/// [`Joiner::resume`] carry back in. `state` restores the math
/// bit-exactly; the rest restores the driver's bookkeeping.
#[derive(Debug, Clone)]
pub struct MemberResume {
    pub state: MemberState,
    /// Steps already trained (the data stream is fast-forwarded past
    /// exactly this many batches on resume).
    pub steps_done: usize,
    pub first_loss: f32,
    pub base_loss: f32,
    pub base_acc: f32,
    /// Loss-curve samples recorded before the preemption, so the final
    /// report's curve spans the full trajectory.
    pub curve: Vec<(usize, f32)>,
}

/// A queued adapter the session hands a running pack at a completion
/// boundary.
pub struct Joiner {
    pub config: LoraConfig,
    /// `Some` when the joiner is a preemption victim re-entering.
    pub resume: Option<MemberResume>,
    /// The session job the adapter was originally submitted under.
    pub from_job: usize,
}

/// What the session's admission closure sees at a boundary.
pub struct BoundaryOffer<'a> {
    /// Configs still training after this boundary.
    pub survivors: Pack,
    /// The bucket currently executing.
    pub bucket: (usize, usize, usize),
    /// The model's full `(n, r, bs)` bucket grid.
    pub buckets: &'a [(usize, usize, usize)],
    /// Devices the pack currently executes on (cross-`d` admission reads
    /// the count; the ids identify the pack's shard workers).
    pub devices: &'a [usize],
    /// Longest remaining step count among the survivors — the
    /// lower bound on how long a queued job would wait for this pack's
    /// devices if not absorbed.
    pub host_remaining: usize,
}

/// What the session's device-retarget closure sees at a boundary: the
/// pack's current execution shape and the length of its next phase. The
/// closure answers with extra device ids to grow onto (acquired from the
/// Resource Monitor, gated on the modeled saving vs the calibrated
/// [`crate::costmodel::throughput::Calib::device_switch_cost`]), or
/// `None` to stay.
pub struct DeviceOffer {
    /// Devices currently held.
    pub d: usize,
    /// Bucket the next phase executes on.
    pub bucket: (usize, usize, usize),
    /// Steps until the next adapter-completion boundary.
    pub phase_steps: usize,
}

/// What the session's stage-retarget closure sees at a boundary: the
/// pack's current pipeline depth and execution shape. The closure
/// answers with a new depth to rebuild the stage workers at (gated
/// session-side on the modeled `(d, s)` phase saving vs the calibrated
/// switch cost), or `None` to stay.
pub struct StageOffer {
    /// Effective pipeline depth currently executing (1 = monolithic).
    pub s: usize,
    /// Devices currently held.
    pub d: usize,
    /// Bucket the next phase executes on.
    pub bucket: (usize, usize, usize),
    /// Steps until the next adapter-completion boundary.
    pub phase_steps: usize,
}

/// The elastic-session control surface of [`run_pack_phased`]. A plain
/// phased run uses [`ElasticCtl::none`]; the session wires all of it.
pub struct ElasticCtl<'a> {
    /// Consult the retarget planner at boundaries (off reproduces the
    /// pre-session pad-to-job-end engine).
    pub rebucket: bool,
    /// Live switch-cost calibration shared across the session's jobs:
    /// the retarget decision reads `estimate()`, every performed switch
    /// `record()`s its measured wall time.
    pub switch_cost: Option<SwitchCost>,
    /// Dispatcher-set preemption flag, checked before every step.
    pub preempt: Option<Arc<AtomicBool>>,
    /// Admission hook: called at every boundary with surviving members;
    /// returns queued adapters to inject. Everything returned **must**
    /// fit some bucket together with the survivors (the session checks
    /// with the same `retarget` machinery; the driver re-validates).
    #[allow(clippy::type_complexity)]
    pub offer: Option<&'a mut dyn FnMut(&BoundaryOffer<'_>) -> Vec<Joiner>>,
    /// Device-retarget hook: called at every boundary with survivors;
    /// returns extra device ids the pack should grow its shard set onto
    /// (the session acquires them from the Resource Monitor, gated on
    /// modeled saving vs the calibrated device-switch cost).
    #[allow(clippy::type_complexity)]
    pub devices: Option<&'a mut dyn FnMut(&DeviceOffer) -> Option<Vec<usize>>>,
    /// Live device-retarget cost calibration: every shard-set rebuild a
    /// retarget triggers `record()`s its measured wall time.
    pub device_cost: Option<SwitchCost>,
    /// Initial pipeline depth for this job (the planner's chosen `s`);
    /// `None` falls back to the `PLORA_STAGES` env knob.
    pub stages0: Option<usize>,
    /// Stage-retarget hook: called at every boundary; returns a new
    /// pipeline depth to rebuild the stage workers at, or `None` to
    /// stay. Like device retargets, only the execution layout changes —
    /// trajectories stay bitwise identical.
    #[allow(clippy::type_complexity)]
    pub stages: Option<&'a mut dyn FnMut(&StageOffer) -> Option<usize>>,
    /// Live stage-retarget cost calibration: every stage-set rebuild
    /// `record()`s its measured wall time.
    pub stage_cost: Option<SwitchCost>,
    /// Live data-parallel efficiency calibration: every executed step
    /// records `(shard count, padded samples, wall seconds)` — the
    /// samples behind `Calib::dp_fit`.
    pub dp_stat: Option<DpStat>,
    /// Speed tier of the executing host. When set, step samples also
    /// feed the per-class accumulator behind `Calib::dp_fit_for` — the
    /// measured per-device-class step times heterogeneous placement
    /// plans on.
    pub device_class: Option<String>,
    /// Resume payloads for the *initial* members (continuation of a
    /// preempted job), keyed by adapter id.
    pub resume: Vec<(usize, MemberResume)>,
}

impl ElasticCtl<'_> {
    /// No elasticity: single fixed bucket, no admission, no preemption.
    pub fn none() -> ElasticCtl<'static> {
        ElasticCtl {
            rebucket: false,
            switch_cost: None,
            preempt: None,
            offer: None,
            devices: None,
            device_cost: None,
            stages0: None,
            stages: None,
            stage_cost: None,
            dp_stat: None,
            device_class: None,
            resume: vec![],
        }
    }

    /// Re-bucketing only (the PR-2 session behavior, now cost-aware).
    pub fn rebucket_only() -> ElasticCtl<'static> {
        ElasticCtl { rebucket: true, ..ElasticCtl::none() }
    }
}

/// Everything a phased run returns.
pub struct PhasedOutcome {
    pub report: JobReport,
    /// Final bucket state (holds every slot of the last phase).
    pub state: TrainState,
    /// Unfinished members checkpointed out by a preemption (empty on a
    /// normal completion).
    pub preempted: Vec<(LoraConfig, MemberResume)>,
}

/// Progress callbacks from a phased packed job (the session maps these
/// onto its public `Event` stream).
pub enum PackPhaseEvent<'a> {
    /// An adapter completed its budget. `state` still holds its slot, so
    /// the caller can extract a true-rank checkpoint before any re-bucket.
    AdapterFinished { slot: usize, report: &'a AdapterReport, state: &'a TrainState },
    /// A queued adapter was admitted into this pack at a boundary.
    AdapterAdmitted { config: &'a LoraConfig, from_job: usize },
    /// The pack moved to a different bucket (grow or shrink).
    Rebucketed {
        from: (usize, usize, usize),
        to: (usize, usize, usize),
        /// Config ids training on the new bucket, in slot order.
        survivors: Vec<usize>,
        /// Measured wall cost of the switch (checkpoint + repack +
        /// executable swap) — feeds the live switch-cost calibration.
        switch_secs: f64,
    },
    /// The pack's device set changed at a boundary (grew onto freed
    /// devices); the shard layout was rebuilt at the new count.
    DeviceRetarget {
        from: usize,
        to: usize,
        /// Measured wall cost of the shard-set rebuild — feeds the live
        /// device-switch-cost calibration.
        switch_secs: f64,
    },
    /// The pack's pipeline depth changed at a boundary; the stage
    /// workers were rebuilt at the new depth (execution layout only —
    /// the trajectory is bitwise unchanged).
    StageRetarget {
        from: usize,
        to: usize,
        /// Measured wall cost of the stage-set rebuild — feeds the live
        /// stage-switch-cost calibration.
        switch_secs: f64,
    },
    /// The job was preempted: the listed config ids were checkpointed
    /// back to the caller (see [`PhasedOutcome::preempted`]).
    Preempted { remaining: Vec<usize> },
}

const INIT_SALT: u64 = 0x706c_6f72_6149_4e49;
const DATA_SALT: u64 = 0x706c_6f72_6144_4154;
const EVAL_SALT: u64 = 0x706c_6f72_6145_5641;

/// Per-adapter stream key: every adapter draws init/train/eval data from
/// its own `(seed, id)`-keyed generator (see module docs).
fn stream_seed(seed: u64, id: usize, salt: u64) -> u64 {
    seed ^ salt ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Run one packed job live on the runtime, data-parallel across
/// `PLORA_DEVICES` local devices (default 1).
pub fn run_pack(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<JobReport> {
    run_pack_full(rt, model, configs, opts).map(|(rep, _)| rep)
}

/// [`run_pack`] on an explicit device [`Allocation`] (benches and tests
/// sweep the device count with it; `run_pack` itself uses
/// [`devices_default`]).
pub fn run_pack_on(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
    alloc: &Allocation,
) -> Result<JobReport> {
    let out =
        run_pack_phased(rt, model, configs, opts, alloc, &mut ElasticCtl::none(), &mut |_| {})?;
    Ok(out.report)
}

/// Like [`run_pack`] but also returns the final [`TrainState`], so callers
/// can slice true-rank adapter checkpoints out of the padded pack tensors.
/// Runs without re-bucketing so the returned state holds *every* adapter's
/// slot; the session uses [`run_pack_phased`] directly for the elastic
/// path (finished adapters are checkpointed from the event stream there).
pub fn run_pack_full(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<(JobReport, TrainState)> {
    let alloc = Allocation::local(devices_default());
    let out =
        run_pack_phased(rt, model, configs, opts, &alloc, &mut ElasticCtl::none(), &mut |_| {})?;
    Ok((out.report, out.state))
}

/// Runtime vectors for the current slot layout: `scale`/`lr` per bucket
/// slot (inert slots keep lr = 0) and true ranks for the rank mask.
fn build_vectors(
    configs: &[LoraConfig],
    slots: &[usize],
    active: &[bool],
    bn: usize,
) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
    let mut scale = vec![0.0f32; bn];
    let mut lrs = vec![0.0f32; bn];
    let mut rks = vec![0usize; bn];
    for (s, &k) in slots.iter().enumerate() {
        let c = &configs[k];
        scale[s] = c.alpha_ratio as f32;
        rks[s] = c.rank;
        if active[s] {
            lrs[s] = c.lr as f32;
        }
    }
    (scale, lrs, rks)
}

/// Base-model quality (B = 0 ⇒ identity adapters) for every slot whose
/// member has none yet (fresh members at job start, freshly admitted
/// joiners; resumed members carry theirs — NaN is the "unset" sentinel).
/// No-op when nothing is fresh.
#[allow(clippy::too_many_arguments)]
fn fill_base_metrics(
    rt: &Runtime,
    mi: &ModelInfo,
    eval_exe: &Executable,
    base: &[HostTensor],
    state: &mut ShardedState,
    cfgs: &[LoraConfig],
    slots: &[usize],
    scale: &[f32],
    bbs: usize,
    opts: &TrainOptions,
    base_l: &mut [f32],
    base_a: &mut [f32],
) -> Result<()> {
    let fresh: Vec<bool> = slots.iter().map(|&k| base_l[k].is_nan()).collect();
    if !fresh.iter().any(|&f| f) {
        return Ok(());
    }
    let (bl, ba) = eval_members(
        rt,
        mi,
        eval_exe,
        base,
        state,
        cfgs,
        slots,
        Some(&fresh),
        scale,
        bbs,
        opts,
    )?;
    for (s, &k) in slots.iter().enumerate() {
        if fresh[s] {
            base_l[k] = bl[s];
            base_a[k] = ba[s];
        }
    }
    Ok(())
}

/// Phased packed training (see module docs). `alloc` is the job's real
/// device allocation — its `n·batch` rows execute data-parallel across
/// the allocated devices through [`ShardedState`], bitwise identically at
/// any device count. `ctl` carries the elastic control surface; with
/// [`ElasticCtl::none`], finished adapters ride the initial bucket as
/// inert slots (zero lr, zero batch) — the pre-session engine behavior.
#[allow(clippy::too_many_arguments)]
pub fn run_pack_phased(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
    alloc: &Allocation,
    ctl: &mut ElasticCtl<'_>,
    on_event: &mut dyn FnMut(PackPhaseEvent<'_>),
) -> Result<PhasedOutcome> {
    if configs.is_empty() {
        return Err(anyhow!("run_pack: empty pack"));
    }
    if alloc.devices.is_empty() {
        return Err(anyhow!("run_pack: empty device allocation"));
    }
    let mut devices: Vec<usize> = alloc.devices.clone();
    let mi = rt.manifest.model(model)?.clone();

    // Growable member set: parallel vecs indexed by member id `k`.
    // Members 0..n0 are the submitted pack; admission pushes more.
    let mut cfgs: Vec<LoraConfig> = configs.to_vec();
    let mut total: Vec<usize> = cfgs.iter().map(|c| opts.budget.steps(c.batch)).collect();
    let mut done: Vec<usize> = vec![0; cfgs.len()];
    let mut first: Vec<f32> = vec![f32::NAN; cfgs.len()];
    let mut last: Vec<f32> = vec![f32::NAN; cfgs.len()];
    let mut base_l: Vec<f32> = vec![f32::NAN; cfgs.len()];
    let mut base_a: Vec<f32> = vec![f32::NAN; cfgs.len()];
    let mut curves: Vec<Vec<(usize, f32)>> = vec![vec![]; cfgs.len()];
    let mut reports: Vec<Option<AdapterReport>> = (0..cfgs.len()).map(|_| None).collect();

    // Initial resume payloads (continuation of a preempted job).
    let mut resume0: std::collections::BTreeMap<usize, MemberResume> =
        std::mem::take(&mut ctl.resume).into_iter().collect();
    for (k, c) in cfgs.iter().enumerate() {
        if let Some(r) = resume0.get_mut(&c.id) {
            if r.steps_done > total[k] {
                bail!("resume: adapter {} did {} of {} steps", c.id, r.steps_done, total[k]);
            }
            done[k] = r.steps_done;
            first[k] = r.first_loss;
            base_l[k] = r.base_loss;
            base_a[k] = r.base_acc;
            curves[k] = std::mem::take(&mut r.curve);
        }
    }

    // Initial bucket: the smallest artifact dominating the full pack shape.
    let n_real = cfgs.len();
    let want_r = cfgs.iter().map(|c| c.rank).max().unwrap();
    let want_bs = cfgs.iter().map(|c| c.batch).max().unwrap();
    let info = rt
        .manifest
        .train_bucket(model, n_real, want_r, want_bs)
        .ok_or_else(|| {
            anyhow!(
                "no train bucket for {model} n={n_real} r={want_r} bs={want_bs} (max n: {})",
                rt.manifest.max_bucket_n(model)
            )
        })?
        .clone();
    let (mut bn, mut br, mut bbs) = (
        info.meta_usize("n").unwrap(),
        info.meta_usize("r").unwrap(),
        info.meta_usize("bs").unwrap(),
    );
    let mut train_exe = rt.executable(&info.name)?;
    let mut eval_exe = rt.executable(&rt.manifest.eval_for(&info)?.name.clone())?;
    let compile_secs = train_exe.compile_secs + eval_exe.compile_secs;
    let first_bucket = (info.name.clone(), bn, br, bbs);

    let base = rt.base_weights(model)?;
    let buckets = rt.manifest.train_buckets(model);
    let (seq, vocab) = (mi.seq, mi.vocab);
    // Live cost model for the retarget decisions (bucket-shape charged).
    let cm = if ctl.rebucket { Some(crate::search::live_cost_model(rt, model)?) } else { None };
    // Device offers are only meaningful when the backend can actually
    // split its fused step — on a fused-only backend (e.g. AOT PJRT) a
    // grant would hold devices that never widen anything.
    let can_shard = rt.shard_exec(model, 1, br, bbs)?.is_some();

    // Bucket-slot occupancy: slots[s] = member index; active[s] marks
    // members still inside their budget. Inactive slots are inert (zero
    // lr, zero batch) until a re-bucket drops them entirely.
    let mut slots: Vec<usize> = (0..n_real).collect();
    let mut active: Vec<bool> = vec![true; n_real];

    // Build the initial state through the same merge path admission uses:
    // fresh members draw their own (seed, id) init stream, resumed members
    // restore params + moments + their own step counter — then wrap it for
    // data-parallel execution on the allocation's devices, each shard
    // stage-pipelined at the requested depth (`(d, s)` composition).
    let mut stages_req = ctl.stages0.unwrap_or_else(stages_default).max(1);
    let mut state = {
        let shell = TrainState::empty(&mi, br);
        let joins: Vec<JoinSource<'_>> = cfgs
            .iter()
            .map(|c| match resume0.get(&c.id) {
                Some(r) => JoinSource::Restore { member: &r.state },
                None => JoinSource::Fresh {
                    seed: stream_seed(opts.seed, c.id, INIT_SALT),
                    rank: c.rank,
                },
            })
            .collect();
        let merged = shell.repack_merge(&[], &joins, bn, br)?;
        ShardedState::new_with_stages(rt, model, merged, bbs, &devices, stages_req)?
    };
    resume0.clear();

    // Per-member data streams, fast-forwarded past already-trained steps.
    let mut sbuf = SampleBuf::new();
    let mut data_rngs: Vec<Rng> = Vec::with_capacity(cfgs.len());
    for (k, c) in cfgs.iter().enumerate() {
        let mut rng = Rng::new(stream_seed(opts.seed, c.id, DATA_SALT));
        for _ in 0..done[k] * c.batch {
            tasks::gen_into(&c.task, &rt.manifest.tokens, &mut rng, seq, vocab, &mut sbuf)?;
        }
        data_rngs.push(rng);
    }

    let (mut scale, mut lrs, mut rks) = build_vectors(&cfgs, &slots, &active, bn);
    let mut rmask = state.rank_mask(&rks)?;

    // Step-persistent batch tensors, refilled in place every step and
    // re-derived (with the state's workspace arena) whenever a boundary
    // merge changes the slot layout. When an adapter finishes, its
    // loss-mask rows are zeroed at the boundary (making its gradients
    // exactly zero thereafter — same trajectory as a per-step-rebuilt
    // mask); its stale token rows are then inert, and every other
    // adapter's computation is independent of its pack neighbours (§3.2).
    let batch_tensors = |bn: usize, bbs: usize| -> Result<(HostTensor, HostTensor, HostTensor)> {
        Ok((
            HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?,
            HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?,
            HostTensor::f32(vec![bn, bbs, seq], vec![0.0; bn * bbs * seq])?,
        ))
    };
    let (mut tok_t, mut tgt_t, mut msk_t) = batch_tensors(bn, bbs)?;

    // Base-model quality (B = 0 ⇒ the adapters are identity). Resumed
    // members carry their base metrics from the original run.
    fill_base_metrics(
        rt,
        &mi,
        &eval_exe,
        &base,
        &mut state,
        &cfgs,
        &slots,
        &scale,
        bbs,
        opts,
        &mut base_l,
        &mut base_a,
    )?;

    let t0 = Instant::now();
    let mut profile = vec![];
    let mut executed = 0usize;
    let mut padded_rows = 0usize;
    let mut rebuckets = 0usize;
    let mut admitted = 0usize;
    let mut dretargets = 0usize;
    let mut d_max = devices.len();
    let mut sretargets = 0usize;
    let mut s_max = state.stages();
    let mut preempted: Vec<(LoraConfig, MemberResume)> = vec![];
    let preempt_flag: Option<&AtomicBool> = ctl.preempt.as_deref();

    'job: while active.iter().any(|&a| a) {
        // Steps until the next adapter-completion boundary.
        let phase = slots
            .iter()
            .zip(&active)
            .filter(|&(_, &a)| a)
            .map(|(&k, _)| total[k] - done[k])
            .min()
            .unwrap();
        for _ in 0..phase {
            if preempt_flag.is_some_and(|f| f.load(Ordering::SeqCst)) {
                // Preempted: checkpoint every unfinished member at true
                // rank (params + moments + its own t) and hand them back.
                let mut remaining = vec![];
                for (s, &k) in slots.iter().enumerate() {
                    if !active[s] {
                        continue;
                    }
                    let c = &cfgs[k];
                    let member = state.inner().extract_member(s, c.rank)?;
                    preempted.push((
                        c.clone(),
                        MemberResume {
                            state: member,
                            steps_done: done[k],
                            first_loss: first[k],
                            base_loss: base_l[k],
                            base_acc: base_a[k],
                            curve: std::mem::take(&mut curves[k]),
                        },
                    ));
                    remaining.push(c.id);
                }
                on_event(PackPhaseEvent::Preempted { remaining });
                break 'job;
            }
            let mut real_tokens = 0usize;
            let mut alive = 0usize;
            {
                let tokens = tok_t.as_i32_mut()?;
                let targets = tgt_t.as_i32_mut()?;
                let mask = msk_t.as_f32_mut()?;
                for s in 0..slots.len() {
                    if !active[s] {
                        continue;
                    }
                    let k = slots[s];
                    let c = &cfgs[k];
                    let tl = &rt.manifest.tokens;
                    for b in 0..c.batch {
                        tasks::gen_into(&c.task, tl, &mut data_rngs[k], seq, vocab, &mut sbuf)?;
                        let smp = &sbuf.sample;
                        let off = (s * bbs + b) * seq;
                        tokens[off..off + seq].copy_from_slice(&smp.tokens);
                        targets[off..off + seq].copy_from_slice(&smp.targets);
                        mask[off..off + seq].copy_from_slice(&smp.mask);
                    }
                    real_tokens += c.batch * seq;
                    alive += 1;
                }
            }
            padded_rows += bn * bbs;
            let s0 = Instant::now();
            let per =
                state.step(&train_exe, &base, &tok_t, &tgt_t, &msk_t, &scale, &lrs, &rmask)?;
            let step_secs = s0.elapsed().as_secs_f64();
            profile.push((real_tokens as f64, alive as f64, step_secs));
            if let Some(ds) = &ctl.dp_stat {
                match &ctl.device_class {
                    Some(class) => {
                        ds.record_class(class, state.parallelism(), (bn * bbs) as f64, step_secs)
                    }
                    None => ds.record(state.parallelism(), (bn * bbs) as f64, step_secs),
                }
            }
            for (s, &k) in slots.iter().enumerate() {
                if !active[s] {
                    continue;
                }
                if first[k].is_nan() {
                    first[k] = per[s];
                }
                last[k] = per[s];
                if opts.log_every > 0 && done[k] % opts.log_every == 0 {
                    curves[k].push((done[k], per[s]));
                }
                done[k] += 1;
            }
            executed += 1;
        }

        // Boundary: evaluate and report the adapters that just finished
        // (survivors keep training — their eval comes at their own exit).
        let finishing: Vec<bool> = (0..slots.len())
            .map(|s| active[s] && done[slots[s]] == total[slots[s]])
            .collect();
        if finishing.iter().any(|&f| f) {
            let (eloss, eacc) = eval_members(
                rt,
                &mi,
                &eval_exe,
                &base,
                &mut state,
                &cfgs,
                &slots,
                Some(&finishing),
                &scale,
                bbs,
                opts,
            )?;
            for s in 0..slots.len() {
                if !finishing[s] {
                    continue;
                }
                let k = slots[s];
                let member = state.inner().extract_member(s, cfgs[k].rank)?;
                let rep = AdapterReport {
                    config: cfgs[k].clone(),
                    steps: total[k],
                    first_loss: first[k],
                    final_loss: last[k],
                    base_loss: base_l[k],
                    base_acc: base_a[k],
                    eval_loss: eloss[s],
                    eval_acc: eacc[s],
                    param_hash: member.param_hash(),
                    curve: std::mem::take(&mut curves[k]),
                };
                on_event(PackPhaseEvent::AdapterFinished {
                    slot: s,
                    report: &rep,
                    state: state.inner(),
                });
                reports[k] = Some(rep);
                active[s] = false;
                // Freeze the slot in the reused batch tensors: zeroing its
                // loss-mask rows makes its gradients exactly zero from
                // here on, so its AdamW moments follow the same pure-decay
                // trajectory as a per-step-rebuilt mask would give (its
                // stale token rows are then irrelevant).
                msk_t.as_f32_mut()?[s * bbs * seq..(s + 1) * bbs * seq].fill(0.0);
            }
        }
        let survivors: Vec<usize> = slots
            .iter()
            .zip(&active)
            .filter(|&(_, &a)| a)
            .map(|(&k, _)| k)
            .collect();
        if survivors.is_empty() {
            break;
        }

        // Offer the boundary to the session: queued adapters may join
        // (cross-`d` included — the offer carries the pack's device set
        // and its longest remaining member, the wait lower bound a
        // queued job compares against).
        let host_remaining = survivors.iter().map(|&k| total[k] - done[k]).max().unwrap_or(0);
        let mut joiners: Vec<Joiner> = vec![];
        if let Some(off) = ctl.offer.as_mut() {
            let bo = BoundaryOffer {
                survivors: Pack::new(survivors.iter().map(|&k| cfgs[k].clone()).collect()),
                bucket: (bn, br, bbs),
                buckets: &buckets,
                devices: &devices,
                host_remaining,
            };
            joiners = (**off)(&bo);
        }

        // Elastic retarget (§4): grow or shrink, switch-cost-aware.
        let surv_pack = Pack::new(survivors.iter().map(|&k| cfgs[k].clone()).collect());
        let join_pack = Pack::new(joiners.iter().map(|j| j.config.clone()).collect());
        let next_phase_steps = survivors
            .iter()
            .map(|&k| total[k] - done[k])
            .chain(joiners.iter().map(|j| {
                let tj = opts.budget.steps(j.config.batch);
                tj - j.resume.as_ref().map_or(0, |r| r.steps_done.min(tj))
            }))
            .min()
            .unwrap_or(0);
        let target = match (&cm, ctl.rebucket) {
            (Some(cm), true) => {
                let sw = ctl
                    .switch_cost
                    .as_ref()
                    .map(|s| s.estimate())
                    .unwrap_or(cm.calib.bucket_switch_cost);
                retarget_bucket(
                    &buckets,
                    &surv_pack,
                    &join_pack,
                    (bn, br, bbs),
                    cm,
                    devices.len(),
                    sw,
                    next_phase_steps,
                )
            }
            _ => None,
        };

        if target.is_some() || !joiners.is_empty() {
            let (nn, nr, nbs) = target.unwrap_or((bn, br, bbs));
            if target.is_none() {
                // Staying on the current bucket: joiners must fit the
                // freed slots (the session offers with the same check).
                let need = survivors.len() + joiners.len();
                let jr = joiners.iter().map(|j| j.config.rank).max().unwrap_or(0);
                let jb = joiners.iter().map(|j| j.config.batch).max().unwrap_or(0);
                if need > bn || jr > br || jb > bbs {
                    bail!(
                        "admission: {} joiners (r≤{jr}, bs≤{jb}) do not fit bucket \
                         ({bn},{br},{bbs}) and no retarget was chosen",
                        joiners.len()
                    );
                }
            }
            // Survivors keep their slot order; joiners fill the next ones.
            let keep: Vec<(usize, usize)> = slots
                .iter()
                .enumerate()
                .filter(|&(s, _)| active[s])
                .map(|(s, &k)| (s, cfgs[k].rank))
                .collect();
            // The measured switch window covers exactly the costs the
            // cost model's `bucket_switch_cost` term stands for: the
            // state repack plus (when the bucket changed) the executable
            // swap. Joiner registration below (notably a resumed member's
            // data-stream fast-forward) is admission cost paid regardless
            // of bucket choice and stays outside the window.
            let sw0 = Instant::now();
            {
                let joins: Vec<JoinSource<'_>> = joiners
                    .iter()
                    .map(|j| match &j.resume {
                        Some(r) => JoinSource::Restore { member: &r.state },
                        None => JoinSource::Fresh {
                            seed: stream_seed(opts.seed, j.config.id, INIT_SALT),
                            rank: j.config.rank,
                        },
                    })
                    .collect();
                // The merge rebuilds the sharded execution layout too
                // (the new bucket's slot count re-partitions across the
                // held devices) — part of the measured switch window.
                let merged = state.inner().repack_merge(&keep, &joins, nn, nr)?;
                state = ShardedState::new_with_stages(
                    rt,
                    model,
                    merged,
                    nbs,
                    &devices,
                    stages_req,
                )?;
                s_max = s_max.max(state.stages());
            }
            let mut switch_secs = sw0.elapsed().as_secs_f64();
            let from = (bn, br, bbs);
            let moved = (nn, nr, nbs) != from;
            let mut new_slots = survivors.clone();
            // Register joiner members and fast-forward their streams.
            for j in joiners {
                let k = cfgs.len();
                let tj = opts.budget.steps(j.config.batch);
                let (d0, f0, bl0, ba0) = match &j.resume {
                    Some(r) => (r.steps_done.min(tj), r.first_loss, r.base_loss, r.base_acc),
                    None => (0, f32::NAN, f32::NAN, f32::NAN),
                };
                let mut rng = Rng::new(stream_seed(opts.seed, j.config.id, DATA_SALT));
                for _ in 0..d0 * j.config.batch {
                    tasks::gen_into(
                        &j.config.task,
                        &rt.manifest.tokens,
                        &mut rng,
                        seq,
                        vocab,
                        &mut sbuf,
                    )?;
                }
                let curve0 = j.resume.map(|r| r.curve).unwrap_or_default();
                cfgs.push(j.config);
                total.push(tj);
                done.push(d0);
                first.push(f0);
                last.push(f32::NAN);
                base_l.push(bl0);
                base_a.push(ba0);
                curves.push(curve0);
                reports.push(None);
                data_rngs.push(rng);
                new_slots.push(k);
                admitted += 1;
                on_event(PackPhaseEvent::AdapterAdmitted {
                    config: &cfgs[k],
                    from_job: j.from_job,
                });
            }
            slots = new_slots;
            active = vec![true; slots.len()];
            if moved {
                let sw1 = Instant::now();
                (bn, br, bbs) = (nn, nr, nbs);
                let new_info = rt
                    .manifest
                    .train_bucket(model, bn, br, bbs)
                    .ok_or_else(|| anyhow!("re-bucket target ({bn},{br},{bbs}) vanished"))?
                    .clone();
                train_exe = rt.executable(&new_info.name)?;
                eval_exe = rt.executable(&rt.manifest.eval_for(&new_info)?.name.clone())?;
                switch_secs += sw1.elapsed().as_secs_f64();
                rebuckets += 1;
                if let Some(sc) = &ctl.switch_cost {
                    sc.record(switch_secs);
                }
                on_event(PackPhaseEvent::Rebucketed {
                    from,
                    to: (bn, br, bbs),
                    survivors: slots.iter().map(|&k| cfgs[k].id).collect(),
                    switch_secs,
                });
            }
            // New slot layout (and possibly shape): fresh batch tensors
            // (the merged state's scratch re-derives its arena the same
            // way on the first step).
            (tok_t, tgt_t, msk_t) = batch_tensors(bn, bbs)?;
        }
        // Device retarget: offer the boundary to the session's device
        // planner — a running pack may grow its shard set onto freed
        // devices (gated session-side on modeled phase saving vs the
        // calibrated device-switch cost). The rebuild only changes the
        // execution layout, never the math, so trajectories stay bitwise
        // identical across retargets. Skipped entirely on fused-only
        // backends: the grant could never widen execution.
        if let (true, Some(doff)) = (can_shard, ctl.devices.as_mut()) {
            let off = DeviceOffer {
                d: devices.len(),
                bucket: (bn, br, bbs),
                phase_steps: next_phase_steps,
            };
            if let Some(extra) = (**doff)(&off) {
                if !extra.is_empty() {
                    let from_d = devices.len();
                    devices.extend(extra);
                    let dv0 = Instant::now();
                    state.set_devices(rt, model, &devices)?;
                    let dv_secs = dv0.elapsed().as_secs_f64();
                    if let Some(dc) = &ctl.device_cost {
                        dc.record(dv_secs);
                    }
                    dretargets += 1;
                    d_max = d_max.max(devices.len());
                    on_event(PackPhaseEvent::DeviceRetarget {
                        from: from_d,
                        to: devices.len(),
                        switch_secs: dv_secs,
                    });
                }
            }
        }
        // Stage retarget: offer the boundary to the session's pipeline
        // planner — the pack may deepen (or flatten) its stage workers
        // for the next phase (gated session-side on the modeled `(d, s)`
        // phase saving vs the calibrated stage-switch cost). Like a
        // device retarget, only the execution layout changes. Skipped on
        // fused-only backends, where a stage split can never engage.
        if let (true, Some(soff)) = (can_shard, ctl.stages.as_mut()) {
            let off = StageOffer {
                s: state.stages(),
                d: devices.len(),
                bucket: (bn, br, bbs),
                phase_steps: next_phase_steps,
            };
            if let Some(new_s) = (**soff)(&off) {
                let new_s = new_s.max(1);
                if new_s != stages_req {
                    let from_s = state.stages();
                    stages_req = new_s;
                    let sv0 = Instant::now();
                    state.set_stages(rt, model, stages_req)?;
                    let sv_secs = sv0.elapsed().as_secs_f64();
                    if let Some(sc) = &ctl.stage_cost {
                        sc.record(sv_secs);
                    }
                    if state.stages() != from_s {
                        sretargets += 1;
                        s_max = s_max.max(state.stages());
                        on_event(PackPhaseEvent::StageRetarget {
                            from: from_s,
                            to: state.stages(),
                            switch_secs: sv_secs,
                        });
                    }
                }
            }
        }
        // Rebuild the per-slot runtime vectors for the next phase, then
        // base-eval any member that has no base metrics yet (freshly
        // admitted joiners; resumed ones carried theirs). No-op at a
        // plain boundary.
        let (s2, l2, r2) = build_vectors(&cfgs, &slots, &active, bn);
        scale = s2;
        lrs = l2;
        rks = r2;
        rmask = state.rank_mask(&rks)?;
        fill_base_metrics(
            rt,
            &mi,
            &eval_exe,
            &base,
            &mut state,
            &cfgs,
            &slots,
            &scale,
            bbs,
            opts,
            &mut base_l,
            &mut base_a,
        )?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let adapters: Vec<AdapterReport> = reports.into_iter().flatten().collect();
    Ok(PhasedOutcome {
        report: JobReport {
            artifact: first_bucket.0,
            bucket_n: first_bucket.1,
            bucket_r: first_bucket.2,
            bucket_bs: first_bucket.3,
            steps: executed,
            wall_secs: wall,
            step_secs: wall / executed.max(1) as f64,
            compile_secs,
            adapters,
            profile,
            padded_rows,
            rebuckets,
            admitted,
            d: d_max,
            dretargets,
            s: s_max,
            sretargets,
        },
        state: state.into_inner(),
        preempted,
    })
}

/// Per-bucket-slot eval `(loss, acc)` averaged over `opts.eval_batches`
/// held-out batches. Each adapter draws exactly `config.batch` rows per
/// batch from its own fresh eval stream (rows beyond stay zero-masked), so
/// its metrics are identical across bucket shapes and runs. With
/// `only = Some(mask)`, slots outside the mask stay fully zero-masked
/// (their results are garbage and must not be read) — boundary evals only
/// pay for the adapters actually finishing there.
#[allow(clippy::too_many_arguments)]
fn eval_members(
    rt: &Runtime,
    mi: &ModelInfo,
    eval_exe: &Executable,
    base: &[HostTensor],
    state: &mut ShardedState,
    configs: &[LoraConfig],
    slots: &[usize],
    only: Option<&[bool]>,
    scale: &[f32],
    bbs: usize,
    opts: &TrainOptions,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let bn = state.inner().n;
    let (seq, vocab) = (mi.seq, mi.vocab);
    let mut loss = vec![0.0f32; bn];
    let mut acc = vec![0.0f32; bn];
    let batches = opts.eval_batches.max(1);
    // Held-out rows come from the process-global stream cache: a tuner
    // re-ranking trials at every rung pays for generation once per
    // `(seed, id)` stream, not once per ranking pass.
    let rows: Vec<Arc<Vec<Sample>>> = slots
        .iter()
        .enumerate()
        .map(|(s, &k)| {
            if let Some(m) = only {
                if !m[s] {
                    return Ok(Arc::new(vec![]));
                }
            }
            let c = &configs[k];
            cached_eval_rows(&rt.manifest.tokens, c, opts.seed, seq, vocab, batches * c.batch)
        })
        .collect::<Result<_>>()?;
    // One set of batch tensors for the whole eval, refilled per batch.
    // Rows outside the written set (padding / masked-out slots) stay zero.
    let mut tok_t = HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?;
    let mut tgt_t = HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?;
    let mut msk_t = HostTensor::f32(vec![bn, bbs, seq], vec![0.0; bn * bbs * seq])?;
    for bi in 0..batches {
        {
            let tokens = tok_t.as_i32_mut()?;
            let targets = tgt_t.as_i32_mut()?;
            let mask = msk_t.as_f32_mut()?;
            for (s, &k) in slots.iter().enumerate() {
                if let Some(m) = only {
                    if !m[s] {
                        continue;
                    }
                }
                let c = &configs[k];
                for b in 0..c.batch {
                    let smp = &rows[s][bi * c.batch + b];
                    let off = (s * bbs + b) * seq;
                    tokens[off..off + seq].copy_from_slice(&smp.tokens);
                    targets[off..off + seq].copy_from_slice(&smp.targets);
                    mask[off..off + seq].copy_from_slice(&smp.mask);
                }
            }
        }
        let (l, a) = state.eval(eval_exe, base, &tok_t, &tgt_t, &msk_t, scale)?;
        for s in 0..bn {
            loss[s] += l[s];
            acc[s] += a[s];
        }
    }
    let kf = batches as f32;
    for s in 0..bn {
        loss[s] /= kf;
        acc[s] /= kf;
    }
    Ok((loss, acc))
}

/// Everything one adapter's held-out rows depend on. Eval streams are
/// keyed per adapter id ([`EVAL_SALT`]), never advanced by training, and
/// consumed front-to-first on every eval — so the i-th row is a pure
/// function of this key and can be generated once per process.
type EvalKey = (u64, usize, String, usize, usize, (i32, i32, i32, i32, i32));

/// One adapter's eval stream: the rows generated so far plus the RNG
/// positioned to extend them (a later eval with more batches appends).
/// Rows are behind an [`Arc`] so a cache hit hands out a reference, not
/// a per-eval clone of every `Sample` under the global lock.
struct EvalStream {
    rng: Rng,
    rows: Arc<Vec<Sample>>,
    /// Last-touched tick for LRU eviction.
    tick: u64,
}

#[derive(Default)]
struct EvalCache {
    streams: std::collections::HashMap<EvalKey, EvalStream>,
    tick: u64,
}

/// Stream-count bound on [`EVAL_CACHE`]: one entry per live (seed,
/// adapter) pair, least-recently-used evicted past this — a backstop so
/// the long-running serve daemon can't accumulate eval rows without
/// limit across tenants. Eviction is purely a perf event: a re-inserted
/// stream regenerates the same bits.
const EVAL_CACHE_CAP: usize = 1024;

static EVAL_CACHE: std::sync::OnceLock<std::sync::Mutex<EvalCache>> = std::sync::OnceLock::new();

/// The first `need` rows of an adapter's held-out eval stream, from the
/// process-global cache. Bit-exact by construction: rows are generated by
/// the same RNG stream in the same order as direct generation, just
/// memoized — a successive-halving tuner evaluating every rung boundary
/// regenerates nothing. The returned `Arc` holds at least `need` rows.
fn cached_eval_rows(
    tl: &TokenLayout,
    c: &LoraConfig,
    seed: u64,
    seq: usize,
    vocab: usize,
    need: usize,
) -> Result<Arc<Vec<Sample>>> {
    let key: EvalKey =
        (seed, c.id, c.task.clone(), seq, vocab, (tl.pad, tl.bos, tl.sep, tl.eos, tl.alpha0));
    let cache = EVAL_CACHE.get_or_init(Default::default);
    let mut cache = cache.lock().unwrap();
    cache.tick += 1;
    let tick = cache.tick;
    if !cache.streams.contains_key(&key) && cache.streams.len() >= EVAL_CACHE_CAP {
        if let Some(oldest) =
            cache.streams.iter().min_by_key(|(_, s)| s.tick).map(|(k, _)| k.clone())
        {
            cache.streams.remove(&oldest);
        }
    }
    let stream = cache.streams.entry(key).or_insert_with(|| EvalStream {
        rng: Rng::new(stream_seed(seed, c.id, EVAL_SALT)),
        rows: Arc::new(vec![]),
        tick,
    });
    stream.tick = tick;
    if stream.rows.len() < need {
        // Clones the backing Vec only if an earlier eval still holds the
        // shorter Arc (rare: evals of one adapter don't overlap).
        let rows = Arc::make_mut(&mut stream.rows);
        let mut sbuf = SampleBuf::new();
        while rows.len() < need {
            tasks::gen_into(&c.task, tl, &mut stream.rng, seq, vocab, &mut sbuf)?;
            rows.push(sbuf.sample.clone());
        }
    }
    Ok(stream.rows.clone())
}

/// Drop the cached eval streams of `adapters` under `seed` — called when
/// a session drains so sweep-scoped streams don't outlive their sweep in
/// a long-running process. Purely a perf event (see [`EVAL_CACHE_CAP`]).
pub fn evict_eval_rows(seed: u64, adapters: impl IntoIterator<Item = usize>) {
    let Some(cache) = EVAL_CACHE.get() else { return };
    let ids: std::collections::BTreeSet<usize> = adapters.into_iter().collect();
    if ids.is_empty() {
        return;
    }
    let mut cache = cache.lock().unwrap();
    cache.streams.retain(|k, _| k.0 != seed || !ids.contains(&k.1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Runtime::load(&dir).unwrap())
    }

    fn cfg(id: usize, task: &str, rank: usize, bs: usize, lr: f64) -> LoraConfig {
        LoraConfig { id, lr, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
    }

    /// End-to-end: a short packed job on the nano model must reduce the
    /// training loss of every adapter (all layers compose: tasks → state →
    /// train artifact → AdamW update → eval artifact).
    #[test]
    fn packed_job_learns_on_nano() {
        let Some(rt) = runtime() else { return };
        let configs = vec![cfg(0, "modadd", 8, 2, 2e-3), cfg(1, "parity", 8, 2, 2e-3)];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 96, epochs: 1 },
            eval_batches: 2,
            seed: 3,
            log_every: 4,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.adapters.len(), 2);
        assert_eq!(rep.steps, 48);
        for a in &rep.adapters {
            assert!(a.first_loss.is_finite() && a.final_loss.is_finite());
            // Held-out eval loss must improve over the base model (B=0 at
            // init ⇒ base_loss is the frozen model's quality).
            assert!(
                a.eval_loss < a.base_loss,
                "{}: eval loss {} vs base {} did not improve",
                a.config.task,
                a.eval_loss,
                a.base_loss
            );
            assert!(!a.curve.is_empty());
        }
        assert!(!rep.profile.is_empty());
        assert!(rep.rank_throughput() > 0.0);
        assert_eq!((rep.rebuckets, rep.admitted), (0, 0));
    }

    /// The bucket mechanism pads a 3-adapter pack onto the n=4 artifact and
    /// the padding slot changes nothing (lr = 0, batch = 0).
    #[test]
    fn bucket_padding_is_inert() {
        let Some(rt) = runtime() else { return };
        let configs = vec![
            cfg(0, "modadd", 8, 1, 5e-3),
            cfg(1, "copy", 8, 1, 5e-3),
            cfg(2, "needle", 8, 1, 5e-3),
        ];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 4, epochs: 1 },
            eval_batches: 1,
            seed: 5,
            log_every: 0,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.bucket_n, 4); // nano grid: n ∈ {1, 2, 4}
        assert_eq!(rep.adapters.len(), 3);
    }

    /// Oversized packs are rejected with a useful error.
    #[test]
    fn oversized_pack_is_rejected() {
        let Some(rt) = runtime() else { return };
        let configs: Vec<_> = (0..64).map(|i| cfg(i, "modadd", 8, 1, 1e-3)).collect();
        let err = run_pack(&rt, "nano", &configs, &TrainOptions::default()).unwrap_err();
        assert!(err.to_string().contains("no train bucket"));
    }

    /// Preempt a mixed-batch pack mid-job (the flag raised at its first
    /// completion boundary), then resume the survivor from its checkpoint
    /// in a *smaller* bucket: every metric of both adapters must be
    /// bit-identical to the uninterrupted run.
    #[test]
    fn preempt_and_resume_is_bit_identical() {
        let Some(rt) = runtime() else { return };
        // bs1 -> 12 steps, bs2 -> 6: parity finishes at the boundary.
        let configs = vec![cfg(0, "modadd", 8, 1, 2e-3), cfg(1, "parity", 8, 2, 2e-3)];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 12, epochs: 1 },
            eval_batches: 1,
            seed: 9,
            log_every: 2, // curve samples span the preemption boundary
        };
        let clean = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(clean.adapters.len(), 2);

        // The event callback raises the preempt flag when parity finishes;
        // the driver observes it before the survivor's next step.
        let flag = Arc::new(AtomicBool::new(false));
        let fl = flag.clone();
        let alloc = Allocation::local(devices_default());
        let mut ctl = ElasticCtl { preempt: Some(flag.clone()), ..ElasticCtl::none() };
        let out = run_pack_phased(&rt, "nano", &configs, &opts, &alloc, &mut ctl, &mut |ev| {
            if matches!(ev, PackPhaseEvent::AdapterFinished { .. }) {
                fl.store(true, Ordering::SeqCst);
            }
        })
        .unwrap();
        assert_eq!(out.report.adapters.len(), 1, "parity finished before the preemption");
        assert_eq!(out.preempted.len(), 1);
        let (pc, pr) = &out.preempted[0];
        assert_eq!(pc.id, 0);
        assert_eq!(pr.steps_done, 6, "preempted right after the 6-step boundary");

        // Resume the survivor alone (bucket (1,8,1), not the original
        // (2,8,2)) from the checkpoint.
        let resume = vec![(pc.id, pr.clone())];
        let mut ctl = ElasticCtl { resume, ..ElasticCtl::none() };
        let done =
            run_pack_phased(&rt, "nano", &configs[..1], &opts, &alloc, &mut ctl, &mut |_| {})
                .unwrap();
        assert!(done.preempted.is_empty());
        assert_eq!(done.report.adapters.len(), 1);
        let (a, b) = (&clean.adapters[0], &done.report.adapters[0]);
        assert_eq!(a.config.id, b.config.id);
        assert_eq!(a.first_loss, b.first_loss, "first loss diverged");
        assert_eq!(a.final_loss, b.final_loss, "final loss diverged");
        assert_eq!(a.eval_loss, b.eval_loss, "eval loss diverged");
        assert_eq!(a.eval_acc, b.eval_acc, "eval acc diverged");
        assert_eq!(a.base_loss, b.base_loss, "base loss diverged");
        assert_eq!(a.steps, b.steps, "reported steps are the adapter's full budget");
        // The curve spans the full trajectory: pre-preemption samples are
        // carried through the checkpoint and re-joined on resume.
        assert!(!a.curve.is_empty());
        assert_eq!(a.curve, b.curve, "loss curve lost samples across preempt/resume");
        // The parity adapter's report from the preempted segment matches
        // the clean run too.
        let (pa, pb) = (&clean.adapters[1], &out.report.adapters[0]);
        assert_eq!(pa.final_loss, pb.final_loss);
        assert_eq!(pa.eval_loss, pb.eval_loss);
    }
}
