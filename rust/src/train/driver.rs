//! Live packed fine-tuning driver: run one job (a pack of LoRA configs
//! sharing a frozen base model) against the AOT train/eval artifacts.
//!
//! This is the L3 side of the paper's Figure 2 workflow — each adapter
//! receives its own task batch; the base weights are shared; per-adapter
//! alpha, learning rate, rank mask and loss mask carry the heterogeneity.
//!
//! Two properties make the driver orchestration-friendly (the `session`
//! subsystem builds on both):
//!
//! - **Per-adapter streams**: an adapter's A-init, train batches and eval
//!   batches come from its own `(seed, id)`-keyed generator, so its whole
//!   trajectory is bit-identical whether it runs solo or packed, and across
//!   bucket shapes (§3.2 "identical to single-adapter fine-tuning").
//! - **Phased execution with re-bucketing**: training advances between
//!   adapter-completion boundaries; when adapters exhaust their budget they
//!   are evaluated, reported through [`PackPhaseEvent`], and — with
//!   `rebucket` on — the survivors are re-packed onto a smaller
//!   `(n, rank, batch)` bucket instead of padding to job end (the
//!   cost-model's phase-wise `job_time`, realized live).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::LoraConfig;
use crate::costmodel::{Pack, TrainBudget};
use crate::planner::rebalance::shrink_bucket;
use crate::runtime::{Executable, HostTensor, ModelInfo, Runtime, TrainState};
use crate::train::tasks;
use crate::util::rng::Rng;

/// Options for one live job.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub budget: TrainBudget,
    /// Held-out batches for eval (before and after fine-tuning).
    pub eval_batches: usize,
    pub seed: u64,
    /// Record the loss curve every `log_every` steps (0 = final only).
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { budget: TrainBudget::default(), eval_batches: 4, seed: 17, log_every: 8 }
    }
}

/// Per-adapter outcome of a job.
#[derive(Debug, Clone)]
pub struct AdapterReport {
    pub config: LoraConfig,
    /// Steps this adapter actually trained (its own budget).
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Eval metrics before any update (base-model quality: B=0 ⇒ Δ=0).
    pub base_loss: f32,
    pub base_acc: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
    /// `(step, train_loss)` samples.
    pub curve: Vec<(usize, f32)>,
}

/// Outcome of one packed fine-tuning job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub artifact: String,
    /// Initial bucket shape executed (≥ requested pack shape; re-bucketing
    /// only ever shrinks it mid-job).
    pub bucket_n: usize,
    pub bucket_r: usize,
    pub bucket_bs: usize,
    pub steps: usize,
    pub wall_secs: f64,
    /// Mean step wall time (excludes compile).
    pub step_secs: f64,
    pub compile_secs: f64,
    pub adapters: Vec<AdapterReport>,
    /// `(real_tokens, alive_adapters, secs)` per step — feeds
    /// `Calib::fit_live` (§4 "profiling data from the first iterations").
    pub profile: Vec<(f64, f64, f64)>,
    /// Padded rows (bucket `n × bs`) summed over executed steps — the
    /// deterministic work proxy that re-bucketing shrinks.
    pub padded_rows: usize,
    /// Bucket shrinks performed at adapter-completion boundaries.
    pub rebuckets: usize,
}

impl JobReport {
    /// Rank-units per second — the DTM objective measured live.
    pub fn rank_throughput(&self) -> f64 {
        let r: usize = self.adapters.iter().map(|a| a.config.rank).sum();
        r as f64 / self.wall_secs.max(1e-9)
    }
}

/// Progress callbacks from a phased packed job (the session maps these
/// onto its public `Event` stream).
pub enum PackPhaseEvent<'a> {
    /// An adapter completed its budget. `state` still holds its slot, so
    /// the caller can extract a true-rank checkpoint before any re-bucket.
    AdapterFinished { slot: usize, report: &'a AdapterReport, state: &'a TrainState },
    /// Surviving adapters were re-packed onto a smaller bucket.
    Rebucketed {
        from: (usize, usize, usize),
        to: (usize, usize, usize),
        /// Config ids still training, in their new slot order.
        survivors: Vec<usize>,
    },
}

const INIT_SALT: u64 = 0x706c_6f72_6149_4e49;
const DATA_SALT: u64 = 0x706c_6f72_6144_4154;
const EVAL_SALT: u64 = 0x706c_6f72_6145_5641;

/// Per-adapter stream key: every adapter draws init/train/eval data from
/// its own `(seed, id)`-keyed generator (see module docs).
fn stream_seed(seed: u64, id: usize, salt: u64) -> u64 {
    seed ^ salt ^ (id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Run one packed job live on the runtime.
pub fn run_pack(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<JobReport> {
    run_pack_full(rt, model, configs, opts).map(|(rep, _)| rep)
}

/// Like [`run_pack`] but also returns the final [`TrainState`], so callers
/// can slice true-rank adapter checkpoints out of the padded pack tensors.
/// Runs without re-bucketing so the returned state holds *every* adapter's
/// slot; the session uses [`run_pack_phased`] directly for the re-bucketing
/// path (finished adapters are checkpointed from the event stream there).
pub fn run_pack_full(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<(JobReport, TrainState)> {
    run_pack_phased(rt, model, configs, opts, false, &mut |_| {})
}

/// Phased packed training (see module docs). With `rebucket` off, finished
/// adapters ride the initial bucket as inert slots (zero lr, zero batch) —
/// the pre-session engine behavior.
pub fn run_pack_phased(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
    rebucket: bool,
    on_event: &mut dyn FnMut(PackPhaseEvent<'_>),
) -> Result<(JobReport, TrainState)> {
    if configs.is_empty() {
        return Err(anyhow!("run_pack: empty pack"));
    }
    let mi = rt.manifest.model(model)?.clone();
    let n_real = configs.len();
    let steps_of: Vec<usize> = configs.iter().map(|c| opts.budget.steps(c.batch)).collect();
    let job_steps = steps_of.iter().copied().max().unwrap_or(0);

    // Initial bucket: the smallest artifact dominating the full pack shape.
    let want_r = configs.iter().map(|c| c.rank).max().unwrap();
    let want_bs = configs.iter().map(|c| c.batch).max().unwrap();
    let info = rt
        .manifest
        .train_bucket(model, n_real, want_r, want_bs)
        .ok_or_else(|| {
            anyhow!(
                "no train bucket for {model} n={n_real} r={want_r} bs={want_bs} (max n: {})",
                rt.manifest.max_bucket_n(model)
            )
        })?
        .clone();
    let (mut bn, mut br, mut bbs) = (
        info.meta_usize("n").unwrap(),
        info.meta_usize("r").unwrap(),
        info.meta_usize("bs").unwrap(),
    );
    let mut train_exe = rt.executable(&info.name)?;
    let mut eval_exe = rt.executable(&rt.manifest.eval_for(&info)?.name.clone())?;
    let compile_secs = train_exe.compile_secs + eval_exe.compile_secs;
    let first_bucket = (info.name.clone(), bn, br, bbs);

    let base = rt.base_weights(model)?;
    let buckets = rt.manifest.train_buckets(model);
    let (seq, vocab) = (mi.seq, mi.vocab);

    // Bucket-slot occupancy: slots[s] = original adapter index; active[s]
    // marks adapters still inside their budget. Inactive slots are inert
    // (zero lr, zero batch) until a re-bucket drops them entirely.
    let mut slots: Vec<usize> = (0..n_real).collect();
    let mut active: Vec<bool> = vec![true; n_real];

    let init_seeds: Vec<u64> =
        configs.iter().map(|c| stream_seed(opts.seed, c.id, INIT_SALT)).collect();
    let ranks: Vec<usize> = configs.iter().map(|c| c.rank).collect();
    let mut state = TrainState::init_per_adapter(&mi, bn, br, &init_seeds, &ranks)?;
    let mut data_rngs: Vec<Rng> = configs
        .iter()
        .map(|c| Rng::new(stream_seed(opts.seed, c.id, DATA_SALT)))
        .collect();

    // Per-bucket-slot runtime vectors, rebuilt whenever membership changes.
    let build_vectors = |slots: &[usize], active: &[bool], bn: usize| {
        let mut scale = vec![0.0f32; bn];
        let mut lrs = vec![0.0f32; bn];
        let mut rks = vec![0usize; bn];
        for (s, &k) in slots.iter().enumerate() {
            let c = &configs[k];
            scale[s] = c.alpha_ratio as f32;
            rks[s] = c.rank;
            if active[s] {
                lrs[s] = c.lr as f32;
            }
        }
        (scale, lrs, rks)
    };
    let (mut scale, mut lrs, mut rks) = build_vectors(&slots, &active, bn);
    let mut rmask = state.rank_mask(&rks)?;

    // Step-persistent batch tensors, refilled in place every step and
    // re-derived (with the state's workspace arena) when a re-bucket
    // changes the bucket shape. When an adapter finishes, its loss-mask
    // rows are zeroed at the boundary (making its gradients exactly zero
    // thereafter — same trajectory as a per-step-rebuilt mask); its stale
    // token rows are then inert, and every other adapter's computation is
    // independent of its pack neighbours (§3.2).
    let batch_tensors = |bn: usize, bbs: usize| -> Result<(HostTensor, HostTensor, HostTensor)> {
        Ok((
            HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?,
            HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?,
            HostTensor::f32(vec![bn, bbs, seq], vec![0.0; bn * bbs * seq])?,
        ))
    };
    let (mut tok_t, mut tgt_t, mut msk_t) = batch_tensors(bn, bbs)?;

    // Base-model quality (B = 0 ⇒ the adapters are identity).
    let (bl, ba) = eval_members(
        rt,
        &mi,
        &eval_exe,
        &base,
        &state,
        configs,
        &slots,
        None,
        &scale,
        bbs,
        opts,
    )?;
    let mut base_loss = vec![0.0f32; n_real];
    let mut base_acc = vec![0.0f32; n_real];
    for (s, &k) in slots.iter().enumerate() {
        base_loss[k] = bl[s];
        base_acc[k] = ba[s];
    }

    let t0 = Instant::now();
    let mut profile = vec![];
    let mut first = vec![f32::NAN; n_real];
    let mut last = vec![f32::NAN; n_real];
    let mut curves: Vec<Vec<(usize, f32)>> = vec![vec![]; n_real];
    let mut reports: Vec<Option<AdapterReport>> = (0..n_real).map(|_| None).collect();
    let mut global_step = 0usize;
    let mut padded_rows = 0usize;
    let mut rebuckets = 0usize;

    while active.iter().any(|&a| a) {
        // Steps until the next adapter-completion boundary.
        let phase = slots
            .iter()
            .zip(&active)
            .filter(|&(_, &a)| a)
            .map(|(&k, _)| steps_of[k] - global_step)
            .min()
            .unwrap();
        for _ in 0..phase {
            let mut real_tokens = 0usize;
            let mut alive = 0usize;
            {
                let tokens = tok_t.as_i32_mut()?;
                let targets = tgt_t.as_i32_mut()?;
                let mask = msk_t.as_f32_mut()?;
                for s in 0..slots.len() {
                    if !active[s] {
                        continue;
                    }
                    let k = slots[s];
                    let c = &configs[k];
                    let tl = &rt.manifest.tokens;
                    for b in 0..c.batch {
                        let smp = tasks::gen(&c.task, tl, &mut data_rngs[k], seq, vocab)?;
                        let off = (s * bbs + b) * seq;
                        tokens[off..off + seq].copy_from_slice(&smp.tokens);
                        targets[off..off + seq].copy_from_slice(&smp.targets);
                        mask[off..off + seq].copy_from_slice(&smp.mask);
                    }
                    real_tokens += c.batch * seq;
                    alive += 1;
                }
            }
            padded_rows += bn * bbs;
            let s0 = Instant::now();
            let per =
                state.step(&train_exe, &base, &tok_t, &tgt_t, &msk_t, &scale, &lrs, &rmask)?;
            profile.push((real_tokens as f64, alive as f64, s0.elapsed().as_secs_f64()));
            for (s, &k) in slots.iter().enumerate() {
                if !active[s] {
                    continue;
                }
                if first[k].is_nan() {
                    first[k] = per[s];
                }
                last[k] = per[s];
                if opts.log_every > 0 && global_step % opts.log_every == 0 {
                    curves[k].push((global_step, per[s]));
                }
            }
            global_step += 1;
        }

        // Boundary: evaluate and report the adapters that just finished
        // (survivors keep training — their eval comes at their own exit).
        let finishing: Vec<bool> = (0..slots.len())
            .map(|s| active[s] && steps_of[slots[s]] == global_step)
            .collect();
        let (eloss, eacc) = eval_members(
            rt,
            &mi,
            &eval_exe,
            &base,
            &state,
            configs,
            &slots,
            Some(&finishing),
            &scale,
            bbs,
            opts,
        )?;
        let mut survivors: Vec<usize> = vec![];
        for s in 0..slots.len() {
            if !active[s] {
                continue;
            }
            let k = slots[s];
            if !finishing[s] {
                survivors.push(k);
                continue;
            }
            let rep = AdapterReport {
                config: configs[k].clone(),
                steps: steps_of[k],
                first_loss: first[k],
                final_loss: last[k],
                base_loss: base_loss[k],
                base_acc: base_acc[k],
                eval_loss: eloss[s],
                eval_acc: eacc[s],
                curve: std::mem::take(&mut curves[k]),
            };
            on_event(PackPhaseEvent::AdapterFinished { slot: s, report: &rep, state: &state });
            reports[k] = Some(rep);
            active[s] = false;
            // Freeze the slot in the reused batch tensors: zeroing its
            // loss-mask rows makes its gradients exactly zero from here
            // on, so its AdamW moments follow the same pure-decay
            // trajectory as a per-step-rebuilt mask would give (its
            // stale token rows are then irrelevant).
            msk_t.as_f32_mut()?[s * bbs * seq..(s + 1) * bbs * seq].fill(0.0);
        }
        if survivors.is_empty() {
            break;
        }

        // Preemptive re-bucketing (§4): consult the planner's balancing
        // side for a strictly smaller bucket admitting the survivors.
        if rebucket {
            let surv = Pack::new(survivors.iter().map(|&k| configs[k].clone()).collect());
            if let Some((nn, nr, nbs)) = shrink_bucket(&buckets, &surv, (bn, br, bbs)) {
                let new_info = rt
                    .manifest
                    .train_bucket(model, nn, nr, nbs)
                    .ok_or_else(|| anyhow!("re-bucket target ({nn},{nr},{nbs}) vanished"))?
                    .clone();
                let mut keep: Vec<(usize, usize)> = vec![];
                let mut new_slots: Vec<usize> = vec![];
                for (s, &k) in slots.iter().enumerate() {
                    if active[s] {
                        keep.push((s, configs[k].rank));
                        new_slots.push(k);
                    }
                }
                state = state.repack(&keep, nn, nr)?;
                let from = (bn, br, bbs);
                slots = new_slots;
                active = vec![true; slots.len()];
                (bn, br, bbs) = (nn, nr, nbs);
                train_exe = rt.executable(&new_info.name)?;
                eval_exe = rt.executable(&rt.manifest.eval_for(&new_info)?.name.clone())?;
                // New bucket shape: fresh batch tensors (the repacked
                // state's scratch re-derives its arena the same way).
                (tok_t, tgt_t, msk_t) = batch_tensors(bn, bbs)?;
                rebuckets += 1;
                on_event(PackPhaseEvent::Rebucketed {
                    from,
                    to: (bn, br, bbs),
                    survivors: slots.iter().map(|&k| configs[k].id).collect(),
                });
            }
        }
        let rebuilt = build_vectors(&slots, &active, bn);
        scale = rebuilt.0;
        lrs = rebuilt.1;
        rks = rebuilt.2;
        rmask = state.rank_mask(&rks)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let adapters: Vec<AdapterReport> = reports
        .into_iter()
        .map(|r| r.expect("every adapter reports at its completion boundary"))
        .collect();
    Ok((
        JobReport {
            artifact: first_bucket.0,
            bucket_n: first_bucket.1,
            bucket_r: first_bucket.2,
            bucket_bs: first_bucket.3,
            steps: job_steps,
            wall_secs: wall,
            step_secs: wall / job_steps.max(1) as f64,
            compile_secs,
            adapters,
            profile,
            padded_rows,
            rebuckets,
        },
        state,
    ))
}

/// Per-bucket-slot eval `(loss, acc)` averaged over `opts.eval_batches`
/// held-out batches. Each adapter draws exactly `config.batch` rows per
/// batch from its own fresh eval stream (rows beyond stay zero-masked), so
/// its metrics are identical across bucket shapes and runs. With
/// `only = Some(mask)`, slots outside the mask stay fully zero-masked
/// (their results are garbage and must not be read) — boundary evals only
/// pay for the adapters actually finishing there.
#[allow(clippy::too_many_arguments)]
fn eval_members(
    rt: &Runtime,
    mi: &ModelInfo,
    eval_exe: &Executable,
    base: &[HostTensor],
    state: &TrainState,
    configs: &[LoraConfig],
    slots: &[usize],
    only: Option<&[bool]>,
    scale: &[f32],
    bbs: usize,
    opts: &TrainOptions,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let bn = state.n;
    let (seq, vocab) = (mi.seq, mi.vocab);
    let mut ergs: Vec<Rng> = slots
        .iter()
        .map(|&k| Rng::new(stream_seed(opts.seed, configs[k].id, EVAL_SALT)))
        .collect();
    let mut loss = vec![0.0f32; bn];
    let mut acc = vec![0.0f32; bn];
    let batches = opts.eval_batches.max(1);
    // One set of batch tensors for the whole eval, refilled per batch.
    // Rows outside the written set (padding / masked-out slots) stay zero.
    let mut tok_t = HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?;
    let mut tgt_t = HostTensor::i32(vec![bn, bbs, seq], vec![0; bn * bbs * seq])?;
    let mut msk_t = HostTensor::f32(vec![bn, bbs, seq], vec![0.0; bn * bbs * seq])?;
    for _ in 0..batches {
        {
            let tokens = tok_t.as_i32_mut()?;
            let targets = tgt_t.as_i32_mut()?;
            let mask = msk_t.as_f32_mut()?;
            for (s, &k) in slots.iter().enumerate() {
                if let Some(m) = only {
                    if !m[s] {
                        continue;
                    }
                }
                let c = &configs[k];
                for b in 0..c.batch {
                    let smp =
                        tasks::gen(&c.task, &rt.manifest.tokens, &mut ergs[s], seq, vocab)?;
                    let off = (s * bbs + b) * seq;
                    tokens[off..off + seq].copy_from_slice(&smp.tokens);
                    targets[off..off + seq].copy_from_slice(&smp.targets);
                    mask[off..off + seq].copy_from_slice(&smp.mask);
                }
            }
        }
        let (l, a) = state.eval(eval_exe, base, &tok_t, &tgt_t, &msk_t, scale)?;
        for s in 0..bn {
            loss[s] += l[s];
            acc[s] += a[s];
        }
    }
    let kf = batches as f32;
    for s in 0..bn {
        loss[s] /= kf;
        acc[s] /= kf;
    }
    Ok((loss, acc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Runtime::load(&dir).unwrap())
    }

    fn cfg(id: usize, task: &str, rank: usize, bs: usize, lr: f64) -> LoraConfig {
        LoraConfig { id, lr, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
    }

    /// End-to-end: a short packed job on the nano model must reduce the
    /// training loss of every adapter (all layers compose: tasks → state →
    /// train artifact → AdamW update → eval artifact).
    #[test]
    fn packed_job_learns_on_nano() {
        let Some(rt) = runtime() else { return };
        let configs = vec![cfg(0, "modadd", 8, 2, 2e-3), cfg(1, "parity", 8, 2, 2e-3)];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 96, epochs: 1 },
            eval_batches: 2,
            seed: 3,
            log_every: 4,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.adapters.len(), 2);
        assert_eq!(rep.steps, 48);
        for a in &rep.adapters {
            assert!(a.first_loss.is_finite() && a.final_loss.is_finite());
            // Held-out eval loss must improve over the base model (B=0 at
            // init ⇒ base_loss is the frozen model's quality).
            assert!(
                a.eval_loss < a.base_loss,
                "{}: eval loss {} vs base {} did not improve",
                a.config.task,
                a.eval_loss,
                a.base_loss
            );
            assert!(!a.curve.is_empty());
        }
        assert!(!rep.profile.is_empty());
        assert!(rep.rank_throughput() > 0.0);
    }

    /// The bucket mechanism pads a 3-adapter pack onto the n=4 artifact and
    /// the padding slot changes nothing (lr = 0, batch = 0).
    #[test]
    fn bucket_padding_is_inert() {
        let Some(rt) = runtime() else { return };
        let configs = vec![
            cfg(0, "modadd", 8, 1, 5e-3),
            cfg(1, "copy", 8, 1, 5e-3),
            cfg(2, "needle", 8, 1, 5e-3),
        ];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 4, epochs: 1 },
            eval_batches: 1,
            seed: 5,
            log_every: 0,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.bucket_n, 4); // nano grid: n ∈ {1, 2, 4}
        assert_eq!(rep.adapters.len(), 3);
    }

    /// Oversized packs are rejected with a useful error.
    #[test]
    fn oversized_pack_is_rejected() {
        let Some(rt) = runtime() else { return };
        let configs: Vec<_> = (0..64).map(|i| cfg(i, "modadd", 8, 1, 1e-3)).collect();
        let err = run_pack(&rt, "nano", &configs, &TrainOptions::default()).unwrap_err();
        assert!(err.to_string().contains("no train bucket"));
    }
}
