//! Live packed fine-tuning driver: run one job (a pack of LoRA configs
//! sharing a frozen base model) against the AOT train/eval artifacts.
//!
//! This is the L3 side of the paper's Figure 2 workflow — each adapter
//! receives its own task batch; the base weights are shared; per-adapter
//! alpha, learning rate, rank mask and loss mask carry the heterogeneity.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::LoraConfig;
use crate::costmodel::TrainBudget;
use crate::runtime::{HostTensor, Runtime, TrainState};
use crate::train::tasks;
use crate::util::rng::Rng;

/// Options for one live job.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub budget: TrainBudget,
    /// Held-out batches for eval (before and after fine-tuning).
    pub eval_batches: usize,
    pub seed: u64,
    /// Record the loss curve every `log_every` steps (0 = final only).
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { budget: TrainBudget::default(), eval_batches: 4, seed: 17, log_every: 8 }
    }
}

/// Per-adapter outcome of a job.
#[derive(Debug, Clone)]
pub struct AdapterReport {
    pub config: LoraConfig,
    /// Steps this adapter actually trained (its own budget).
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Eval metrics before any update (base-model quality: B=0 ⇒ Δ=0).
    pub base_loss: f32,
    pub base_acc: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
    /// `(step, train_loss)` samples.
    pub curve: Vec<(usize, f32)>,
}

/// Outcome of one packed fine-tuning job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub artifact: String,
    /// Bucket shape actually executed (≥ requested pack shape).
    pub bucket_n: usize,
    pub bucket_r: usize,
    pub bucket_bs: usize,
    pub steps: usize,
    pub wall_secs: f64,
    /// Mean step wall time (excludes compile).
    pub step_secs: f64,
    pub compile_secs: f64,
    pub adapters: Vec<AdapterReport>,
    /// `(real_tokens, n_adapters, secs)` per sampled step — feeds
    /// `Calib::fit_live` (§4 "profiling data from the first iterations").
    pub profile: Vec<(f64, f64, f64)>,
}

impl JobReport {
    /// Rank-units per second — the DTM objective measured live.
    pub fn rank_throughput(&self) -> f64 {
        let r: usize = self.adapters.iter().map(|a| a.config.rank).sum();
        r as f64 / self.wall_secs.max(1e-9)
    }
}

/// Run one packed job live on the PJRT runtime.
pub fn run_pack(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<JobReport> {
    run_pack_full(rt, model, configs, opts).map(|(rep, _)| rep)
}

/// Like [`run_pack`] but also returns the final [`TrainState`], so callers
/// (the execution engine) can slice true-rank adapter checkpoints out of
/// the padded pack tensors.
pub fn run_pack_full(
    rt: &Runtime,
    model: &str,
    configs: &[LoraConfig],
    opts: &TrainOptions,
) -> Result<(JobReport, TrainState)> {
    if configs.is_empty() {
        return Err(anyhow!("run_pack: empty pack"));
    }
    let mi = rt.manifest.model(model)?.clone();
    let want_n = configs.len();
    let want_r = configs.iter().map(|c| c.rank).max().unwrap();
    let want_bs = configs.iter().map(|c| c.batch).max().unwrap();
    let info = rt
        .manifest
        .train_bucket(model, want_n, want_r, want_bs)
        .ok_or_else(|| {
            anyhow!("no train bucket for {model} n={want_n} r={want_r} bs={want_bs} (max n: {})",
                rt.manifest.max_bucket_n(model))
        })?
        .clone();
    let (n, r, bs) = (
        info.meta_usize("n").unwrap(),
        info.meta_usize("r").unwrap(),
        info.meta_usize("bs").unwrap(),
    );
    let train_exe = rt.executable(&info.name)?;
    let eval_exe = rt.executable(&rt.manifest.eval_for(&info)?.name.clone())?;
    let compile_secs = train_exe.compile_secs + eval_exe.compile_secs;

    let base = rt.base_weights(model)?;
    let mut state = TrainState::init(&mi, n, r, opts.seed);
    let mut rng = Rng::new(opts.seed ^ 0x9e37_79b9_7f4a_7c15);

    // Per-slot runtime vectors; padding slots (beyond the real pack) train
    // nothing: lr 0, scale 0, batch 0.
    let mut scale = vec![0.0f32; n];
    let mut lr = vec![0.0f32; n];
    let mut ranks = vec![r; n];
    let mut real_bs = vec![0usize; n];
    let mut task_names: Vec<&str> = vec!["modadd"; n];
    let mut adapter_steps = vec![0usize; n];
    for (i, c) in configs.iter().enumerate() {
        scale[i] = c.alpha_ratio as f32;
        lr[i] = c.lr as f32;
        ranks[i] = c.rank;
        real_bs[i] = c.batch;
        task_names[i] = &c.task;
        adapter_steps[i] = opts.budget.steps(c.batch);
    }
    let rmask = state.rank_mask(&ranks)?;
    let job_steps = adapter_steps.iter().copied().max().unwrap_or(0);

    // Base-model quality (B = 0 ⇒ the adapters are identity).
    let (base_loss, base_acc) =
        eval_avg(rt, &state, &eval_exe, &base, &task_names, &scale, bs, &mi, opts)?;

    let t0 = Instant::now();
    let mut profile = vec![];
    let mut first = vec![f32::NAN; n];
    let mut last = vec![f32::NAN; n];
    let mut curves: Vec<Vec<(usize, f32)>> = vec![vec![]; n];
    for step in 0..job_steps {
        // Adapters past their budget stop: zero lr and batch.
        let mut lr_now = lr.clone();
        let mut bs_now = real_bs.clone();
        for i in 0..n {
            if step >= adapter_steps[i] {
                lr_now[i] = 0.0;
                bs_now[i] = 0;
            }
        }
        let pb = tasks::packed_batch(
            &task_names,
            &rt.manifest.tokens,
            &mut rng,
            bs,
            mi.seq,
            mi.vocab,
            Some(&bs_now),
        )?;
        let real_tokens: usize = bs_now.iter().map(|&b| b * mi.seq).sum();
        let s0 = Instant::now();
        let per = state.step(
            &train_exe,
            &base,
            pb.tokens,
            pb.targets,
            pb.mask,
            &scale,
            &lr_now,
            &rmask,
        )?;
        profile.push((real_tokens as f64, want_n as f64, s0.elapsed().as_secs_f64()));
        for i in 0..want_n {
            if step < adapter_steps[i] {
                if first[i].is_nan() {
                    first[i] = per[i];
                }
                last[i] = per[i];
                if opts.log_every > 0 && step % opts.log_every == 0 {
                    curves[i].push((step, per[i]));
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let (eval_loss, eval_acc) =
        eval_avg(rt, &state, &eval_exe, &base, &task_names, &scale, bs, &mi, opts)?;

    let adapters = configs
        .iter()
        .enumerate()
        .map(|(i, c)| AdapterReport {
            config: c.clone(),
            steps: adapter_steps[i],
            first_loss: first[i],
            final_loss: last[i],
            base_loss: base_loss[i],
            base_acc: base_acc[i],
            eval_loss: eval_loss[i],
            eval_acc: eval_acc[i],
            curve: std::mem::take(&mut curves[i]),
        })
        .collect();

    Ok((
        JobReport {
            artifact: info.name.clone(),
            bucket_n: n,
            bucket_r: r,
            bucket_bs: bs,
            steps: job_steps,
            wall_secs: wall,
            step_secs: wall / job_steps.max(1) as f64,
            compile_secs,
            adapters,
            profile,
        },
        state,
    ))
}

/// Average per-adapter eval (loss, acc) over `opts.eval_batches` held-out
/// batches (deterministic eval seed, disjoint from the train stream).
#[allow(clippy::too_many_arguments)]
fn eval_avg(
    rt: &Runtime,
    state: &TrainState,
    eval_exe: &crate::runtime::Executable,
    base: &[HostTensor],
    task_names: &[&str],
    scale: &[f32],
    bs: usize,
    mi: &crate::runtime::ModelInfo,
    opts: &TrainOptions,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let n = task_names.len();
    let mut rng = Rng::new(opts.seed ^ 0x5851_f42d_4c95_7f2d);
    let mut loss = vec![0.0f32; n];
    let mut acc = vec![0.0f32; n];
    for _ in 0..opts.eval_batches.max(1) {
        let pb = tasks::packed_batch(task_names, &rt.manifest.tokens, &mut rng, bs, mi.seq, mi.vocab, None)?;
        let (l, a) = state.eval(eval_exe, base, pb.tokens, pb.targets, pb.mask, scale)?;
        for i in 0..n {
            loss[i] += l[i];
            acc[i] += a[i];
        }
    }
    let k = opts.eval_batches.max(1) as f32;
    for i in 0..n {
        loss[i] /= k;
        acc[i] /= k;
    }
    Ok((loss, acc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Runtime::load(&dir).unwrap())
    }

    fn cfg(id: usize, task: &str, rank: usize, bs: usize, lr: f64) -> LoraConfig {
        LoraConfig { id, lr, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
    }

    /// End-to-end: a short packed job on the nano model must reduce the
    /// training loss of every adapter (all layers compose: tasks → state →
    /// PJRT train artifact → AdamW update → eval artifact).
    #[test]
    fn packed_job_learns_on_nano() {
        let Some(rt) = runtime() else { return };
        let configs = vec![cfg(0, "modadd", 8, 2, 2e-3), cfg(1, "parity", 8, 2, 2e-3)];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 96, epochs: 1 },
            eval_batches: 2,
            seed: 3,
            log_every: 4,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.adapters.len(), 2);
        assert_eq!(rep.steps, 48);
        for a in &rep.adapters {
            assert!(a.first_loss.is_finite() && a.final_loss.is_finite());
            // Held-out eval loss must improve over the base model (B=0 at
            // init ⇒ base_loss is the frozen model's quality).
            assert!(
                a.eval_loss < a.base_loss,
                "{}: eval loss {} vs base {} did not improve",
                a.config.task,
                a.eval_loss,
                a.base_loss
            );
            assert!(!a.curve.is_empty());
        }
        assert!(!rep.profile.is_empty());
        assert!(rep.rank_throughput() > 0.0);
    }

    /// The bucket mechanism pads a 3-adapter pack onto the n=4 artifact and
    /// the padding slot changes nothing (lr = 0, batch = 0).
    #[test]
    fn bucket_padding_is_inert() {
        let Some(rt) = runtime() else { return };
        let configs = vec![
            cfg(0, "modadd", 8, 1, 5e-3),
            cfg(1, "copy", 8, 1, 5e-3),
            cfg(2, "needle", 8, 1, 5e-3),
        ];
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 4, epochs: 1 },
            eval_batches: 1,
            seed: 5,
            log_every: 0,
        };
        let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
        assert_eq!(rep.bucket_n, 4); // nano grid: n ∈ {1, 2, 4}
        assert_eq!(rep.adapters.len(), 3);
    }

    /// Oversized packs are rejected with a useful error.
    #[test]
    fn oversized_pack_is_rejected() {
        let Some(rt) = runtime() else { return };
        let configs: Vec<_> = (0..64).map(|i| cfg(i, "modadd", 8, 1, 1e-3)).collect();
        let err = run_pack(&rt, "nano", &configs, &TrainOptions::default()).unwrap_err();
        assert!(err.to_string().contains("no train bucket"));
    }
}
