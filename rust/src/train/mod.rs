//! Live fine-tuning: synthetic task generators (the Rust twin of
//! `python/compile/tasks.py`) and the packed-job train driver that replays
//! the AOT train/eval artifacts via PJRT.

pub mod driver;
pub mod tasks;

pub use driver::{
    devices_default, evict_eval_rows, run_pack, run_pack_full, run_pack_on, run_pack_phased,
    AdapterReport, BoundaryOffer, DeviceOffer, ElasticCtl, JobReport, Joiner, MemberResume,
    PackPhaseEvent, PhasedOutcome, TrainOptions,
};
pub use tasks::{packed_batch, PackedBatch, Sample, SampleBuf, TASKS};
