//! Live fine-tuning: synthetic task generators (the Rust twin of
//! `python/compile/tasks.py`) and the packed-job train driver that replays
//! the AOT train/eval artifacts via PJRT.

pub mod driver;
pub mod tasks;

pub use driver::{
    run_pack, run_pack_full, run_pack_phased, AdapterReport, BoundaryOffer, ElasticCtl,
    JobReport, Joiner, MemberResume, PackPhaseEvent, PhasedOutcome, TrainOptions,
};
pub use tasks::{packed_batch, PackedBatch, Sample, SampleBuf, TASKS};
