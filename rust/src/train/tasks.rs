//! Synthetic downstream tasks — the Rust twin of
//! `python/compile/tasks.py` (keep the two in lock-step; the shared token
//! layout is recorded in `artifacts/manifest.json`).
//!
//! The paper evaluates on GSM8K / mrpc / cola / wnli; this environment has
//! no model/data downloads (repro band 0/5), so four synthetic seq2seq
//! skills play their role (DESIGN.md §3): `modadd` (math reasoning),
//! `copy` (language understanding), `parity` (logic), `needle` (lookup).
//! Each sample is `(tokens, targets, loss_mask)` of fixed length `seq`,
//! with the mask set exactly on answer positions.

use anyhow::{bail, Result};

use crate::runtime::manifest::TokenLayout;
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

/// The four tasks, in manifest order.
pub const TASKS: [&str; 4] = ["modadd", "copy", "parity", "needle"];

/// One generated sample.
#[derive(Debug, Clone, Default)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Reusable generation scratch: the output [`Sample`] plus every staging
/// buffer the task generators need. Hot-path callers (the train driver's
/// per-step batch fill, the boundary evals) hold one `SampleBuf` and call
/// [`gen_into`] — after the first sample, generation performs **no
/// allocation at all** (the ROADMAP "pool the task-generator sample
/// allocations" item). RNG draw order is identical to the pre-pooling
/// generators, so every `(seed, id)` data stream is bit-unchanged.
#[derive(Debug, Clone, Default)]
pub struct SampleBuf {
    pub sample: Sample,
    /// `seq + 1` staging row `finalize` splits into tokens/targets.
    full: Vec<i32>,
    /// The raw task sequence being composed.
    stage: Vec<i32>,
    /// `needle` key/value scratch.
    keys: Vec<i32>,
    vals: Vec<i32>,
}

impl SampleBuf {
    pub fn new() -> SampleBuf {
        SampleBuf::default()
    }
}

/// Build `(tokens, targets, mask)` in `buf.sample` from the staged full
/// sequence + answer span `[lo, hi)` in *full-sequence* coordinates
/// (tasks.py `_finalize`).
fn finalize(tl: &TokenLayout, seq: usize, lo: usize, hi: usize, buf: &mut SampleBuf) {
    let SampleBuf { sample, full, stage, .. } = buf;
    full.clear();
    full.resize(seq + 1, tl.pad);
    let l = stage.len().min(seq + 1);
    full[..l].copy_from_slice(&stage[..l]);
    sample.tokens.clear();
    sample.tokens.extend_from_slice(&full[..seq]);
    sample.targets.clear();
    sample.targets.extend_from_slice(&full[1..]);
    sample.mask.clear();
    sample.mask.resize(seq, 0.0);
    let lo = lo.saturating_sub(1);
    let hi = hi.saturating_sub(1).min(seq);
    for m in sample.mask.iter_mut().take(hi).skip(lo) {
        *m = 1.0;
    }
}

/// `a + b = c (mod P)` — mathematical reasoning (gsm8k stand-in).
pub fn gen_modadd(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize) -> Sample {
    alloc_gen(|buf| gen_modadd_into(tl, rng, seq, vocab, buf))
}

fn gen_modadd_into(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize, buf: &mut SampleBuf) {
    let p = (vocab as i64 - tl.alpha0 as i64).min(97) as u64;
    let a = rng.below(p) as i32;
    let b = rng.below(p) as i32;
    let c = (a + b) % p as i32;
    buf.stage.clear();
    buf.stage
        .extend([tl.bos, tl.alpha0 + a, tl.alpha0 + b, tl.sep, tl.alpha0 + c, tl.eos]);
    finalize(tl, seq, 4, 5, buf)
}

/// Copy a random string after SEP — language understanding (mrpc stand-in).
pub fn gen_copy(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize) -> Sample {
    alloc_gen(|buf| gen_copy_into(tl, rng, seq, vocab, buf))
}

fn gen_copy_into(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize, buf: &mut SampleBuf) {
    let alpha = (vocab as i64 - tl.alpha0 as i64).min(64) as u64;
    let ln = (seq - 3) / 2;
    let s = &mut buf.stage;
    s.clear();
    s.push(tl.bos);
    for _ in 0..ln {
        s.push(tl.alpha0 + rng.below(alpha) as i32);
    }
    s.push(tl.sep);
    for i in 0..ln {
        let t = s[1 + i];
        s.push(t);
    }
    s.push(tl.eos);
    finalize(tl, seq, ln + 2, 2 * ln + 2, buf)
}

/// Parity of a bit string — logic reasoning (wnli stand-in).
pub fn gen_parity(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize) -> Sample {
    alloc_gen(|buf| gen_parity_into(tl, rng, seq, vocab, buf))
}

fn gen_parity_into(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize, buf: &mut SampleBuf) {
    let _ = vocab;
    let ln = seq.saturating_sub(4).max(1);
    let s = &mut buf.stage;
    s.clear();
    s.push(tl.bos);
    let mut sum = 0i32;
    for _ in 0..ln {
        let b = rng.below(2) as i32;
        sum += b;
        s.push(tl.alpha0 + b);
    }
    s.extend([tl.sep, tl.alpha0 + sum % 2, tl.eos]);
    finalize(tl, seq, ln + 2, ln + 3, buf)
}

/// Key-value retrieval — commonsense/lookup (cola stand-in).
pub fn gen_needle(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize) -> Sample {
    alloc_gen(|buf| gen_needle_into(tl, rng, seq, vocab, buf))
}

fn gen_needle_into(tl: &TokenLayout, rng: &mut Rng, seq: usize, vocab: usize, buf: &mut SampleBuf) {
    let nk = ((seq - 5) / 2).min(8);
    let key_alpha = ((vocab as i64 - tl.alpha0 as i64) / 2).min(32) as usize;
    let val_base = tl.alpha0 + key_alpha as i32;
    let keys = &mut buf.keys;
    keys.clear();
    keys.extend(0..key_alpha as i32);
    rng.shuffle(keys);
    keys.truncate(nk);
    let vals = &mut buf.vals;
    vals.clear();
    for _ in 0..nk {
        vals.push(rng.below(key_alpha as u64) as i32);
    }
    let qi = rng.usize_below(nk);
    let s = &mut buf.stage;
    s.clear();
    s.push(tl.bos);
    for (k, v) in keys.iter().zip(vals.iter()) {
        s.extend([tl.alpha0 + k, val_base + v]);
    }
    s.extend([tl.sep, tl.alpha0 + keys[qi], tl.sep, val_base + vals[qi], tl.eos]);
    finalize(tl, seq, 2 * nk + 4, 2 * nk + 5, buf)
}

/// Allocating convenience wrapper used by the by-value `gen_*` entry
/// points (tests, one-shot callers).
fn alloc_gen(f: impl FnOnce(&mut SampleBuf)) -> Sample {
    let mut buf = SampleBuf::new();
    f(&mut buf);
    buf.sample
}

/// Generate one sample of `task` into `buf.sample`, reusing every staging
/// buffer (the zero-allocation hot path).
pub fn gen_into(
    task: &str,
    tl: &TokenLayout,
    rng: &mut Rng,
    seq: usize,
    vocab: usize,
    buf: &mut SampleBuf,
) -> Result<()> {
    match task {
        "modadd" => gen_modadd_into(tl, rng, seq, vocab, buf),
        "copy" => gen_copy_into(tl, rng, seq, vocab, buf),
        "parity" => gen_parity_into(tl, rng, seq, vocab, buf),
        "needle" => gen_needle_into(tl, rng, seq, vocab, buf),
        other => bail!("unknown task '{other}'"),
    }
    Ok(())
}

/// Generate one sample of `task` (allocating; prefer [`gen_into`] on hot
/// paths).
pub fn gen(
    task: &str,
    tl: &TokenLayout,
    rng: &mut Rng,
    seq: usize,
    vocab: usize,
) -> Result<Sample> {
    let mut buf = SampleBuf::new();
    gen_into(task, tl, rng, seq, vocab, &mut buf)?;
    Ok(buf.sample)
}

/// A packed batch for `n` adapters: `(n, bs, seq)` tensors ready for the
/// train/eval artifacts. Adapter `i` draws `real_bs[i] ≤ bs` samples of its
/// own task; padding rows stay all-zero with zero loss mask
/// (heterogeneous batch sizes inside a pack, DESIGN.md §2).
pub struct PackedBatch {
    pub tokens: HostTensor,
    pub targets: HostTensor,
    pub mask: HostTensor,
}

pub fn packed_batch(
    tasks: &[&str],
    tl: &TokenLayout,
    rng: &mut Rng,
    bs: usize,
    seq: usize,
    vocab: usize,
    real_bs: Option<&[usize]>,
) -> Result<PackedBatch> {
    let n = tasks.len();
    let mut tokens = vec![0i32; n * bs * seq];
    let mut targets = vec![0i32; n * bs * seq];
    let mut mask = vec![0.0f32; n * bs * seq];
    for (i, task) in tasks.iter().enumerate() {
        let rb = real_bs.map(|r| r[i]).unwrap_or(bs);
        if rb > bs {
            bail!("adapter {i}: real batch {rb} exceeds bucket batch {bs}");
        }
        for b in 0..rb {
            let s = gen(task, tl, rng, seq, vocab)?;
            let off = (i * bs + b) * seq;
            tokens[off..off + seq].copy_from_slice(&s.tokens);
            targets[off..off + seq].copy_from_slice(&s.targets);
            mask[off..off + seq].copy_from_slice(&s.mask);
        }
    }
    Ok(PackedBatch {
        tokens: HostTensor::i32(vec![n, bs, seq], tokens)?,
        targets: HostTensor::i32(vec![n, bs, seq], targets)?,
        mask: HostTensor::f32(vec![n, bs, seq], mask)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> TokenLayout {
        TokenLayout { pad: 0, bos: 1, sep: 2, eos: 3, alpha0: 8 }
    }

    fn check_sample(s: &Sample, seq: usize, vocab: usize) {
        assert_eq!(s.tokens.len(), seq);
        assert_eq!(s.targets.len(), seq);
        assert_eq!(s.mask.len(), seq);
        assert!(s.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)));
        assert!(s.targets.iter().all(|&t| (0..vocab as i32).contains(&t)));
        let m: f32 = s.mask.iter().sum();
        assert!(m >= 1.0, "answer span must be maskable");
        // targets are the one-step shift of tokens
        for i in 0..seq - 1 {
            assert_eq!(s.targets[i], s.tokens[i + 1]);
        }
    }

    #[test]
    fn all_tasks_generate_valid_samples() {
        let tl = tl();
        let mut rng = Rng::new(3);
        for task in TASKS {
            for _ in 0..50 {
                let s = gen(task, &tl, &mut rng, 32, 256).unwrap();
                check_sample(&s, 32, 256);
            }
        }
    }

    #[test]
    fn modadd_answer_is_correct_mod_sum() {
        let tl = tl();
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let s = gen_modadd(&tl, &mut rng, 32, 256).unwrap_sample();
            let (a, b) = (s.tokens[1] - tl.alpha0, s.tokens[2] - tl.alpha0);
            // answer token is at full[4] = tokens[4]
            assert_eq!(s.tokens[4] - tl.alpha0, (a + b) % 97);
            // masked position predicts it: mask[3] == 1, targets[3] == answer
            assert_eq!(s.mask[3], 1.0);
            assert_eq!(s.targets[3], s.tokens[4]);
        }
    }

    // gen_modadd returns Sample directly; tiny shim so the test above reads
    // uniformly with fallible `gen`.
    trait UnwrapSample {
        fn unwrap_sample(self) -> Sample;
    }
    impl UnwrapSample for Sample {
        fn unwrap_sample(self) -> Sample {
            self
        }
    }

    #[test]
    fn parity_answer_matches_bit_sum() {
        let tl = tl();
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let s = gen_parity(&tl, &mut rng, 16, 256);
            let ln = 12;
            let bits: i32 = s.tokens[1..1 + ln].iter().map(|&b| b - tl.alpha0).sum();
            assert_eq!(s.tokens[ln + 2] - tl.alpha0, bits % 2);
        }
    }

    #[test]
    fn needle_answer_is_queried_value() {
        let tl = tl();
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let s = gen_needle(&tl, &mut rng, 32, 256);
            let nk = 8.min((32 - 5) / 2);
            let key_alpha = ((256 - 8) / 2).min(32);
            let val_base = tl.alpha0 + key_alpha;
            // Find the queried key (position 2nk+2) among the pairs.
            let query = s.tokens[2 * nk + 2];
            let answer = s.tokens[2 * nk + 4];
            let mut found = false;
            for pair in 0..nk {
                if s.tokens[1 + 2 * pair] == query {
                    assert_eq!(s.tokens[2 + 2 * pair], answer);
                    found = true;
                }
            }
            assert!(found, "query key must appear among pairs");
            assert!(answer >= val_base);
        }
    }

    #[test]
    fn packed_batch_pads_heterogeneous_batches() {
        let tl = tl();
        let mut rng = Rng::new(13);
        let pb =
            packed_batch(&["modadd", "copy"], &tl, &mut rng, 4, 32, 256, Some(&[1, 4])).unwrap();
        assert_eq!(pb.tokens.shape, vec![2, 4, 32]);
        let mask = pb.mask.as_f32().unwrap();
        // Adapter 0 rows 1..4 are padding: zero mask.
        let row = |i: usize, b: usize| &mask[(i * 4 + b) * 32..(i * 4 + b + 1) * 32];
        assert!(row(0, 0).iter().sum::<f32>() > 0.0);
        for b in 1..4 {
            assert_eq!(row(0, b).iter().sum::<f32>(), 0.0);
        }
        for b in 0..4 {
            assert!(row(1, b).iter().sum::<f32>() > 0.0);
        }
        // Oversized real batch is rejected.
        assert!(packed_batch(&["modadd"], &tl, &mut rng, 2, 32, 256, Some(&[3])).is_err());
    }
}
