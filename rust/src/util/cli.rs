//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Grammar: `plora <subcommand> [--flag] [--key value] [positional...]`.
//! Every flag lookup is typed and records the flag for `--help` synthesis.

use std::collections::BTreeMap;

/// Flags that never take a value, so `--verbose out.json` leaves `out.json`
/// positional. Space-separated `--key value` is otherwise ambiguous;
/// `--key=value` always works regardless of this list.
const KNOWN_BOOLS: &[&str] = &[
    "help", "verbose", "quiet", "json", "force", "a10", "qlora", "live",
    "sim", "packed", "sequential", "markdown", "list", "fast", "no-rebucket",
    "elastic", "grow-devices", "warn-only", "update-baseline", "daemon",
    "digest",
];

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an explicit list (testable) — `argv[0]` must be dropped.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args {
            subcommand: None,
            positional: vec![],
            flags: BTreeMap::new(),
            bools: vec![],
        };
        let mut items: Vec<String> = it.into_iter().collect();
        items.reverse();
        while let Some(a) = items.pop() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if KNOWN_BOOLS.contains(&name) {
                    out.bools.push(name.to_string());
                } else if items.last().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = items.pop().unwrap();
                    out.flags.entry(name.to_string()).or_default().push(v);
                } else {
                    out.bools.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected number, got '{v}'")),
        }
    }

    /// Comma-separated list flag: `--sizes 1,2,8`.
    pub fn list_usize(&self, name: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|_| anyhow::anyhow!("--{name}: bad item '{s}'")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = mk("plan --gpus 8 --model tiny --verbose file.json");
        assert_eq!(a.subcommand.as_deref(), Some("plan"));
        assert_eq!(a.get("gpus"), Some("8"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["file.json"]);
    }

    #[test]
    fn eq_form_and_typed() {
        let a = mk("run --steps=200 --lr 0.5");
        assert_eq!(a.usize("steps", 0).unwrap(), 200);
        assert!((a.f64("lr", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn list_flag() {
        let a = mk("bench --ns 1,2,8,32");
        assert_eq!(a.list_usize("ns", &[]).unwrap(), vec![1, 2, 8, 32]);
        assert_eq!(a.list_usize("other", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn bool_flag_before_positional_consumes_nothing_when_next_is_flag() {
        let a = mk("run --fast --steps 3");
        assert!(a.flag("fast"));
        assert_eq!(a.usize("steps", 0).unwrap(), 3);
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = mk("run --steps abc");
        assert!(a.usize("steps", 0).is_err());
    }
}
