//! FNV-1a 64-bit hashing (no hash crates in the offline set).
//!
//! Used for the trace digests and golden trajectory hashes: the algorithm
//! is fully specified by its two constants, so fingerprints are stable
//! across platforms, toolchains and process runs — unlike
//! `std::collections::hash_map::DefaultHasher`, whose output is
//! deliberately randomized per process.

/// Incremental FNV-1a 64-bit hasher.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write(&x.to_le_bytes());
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    pub fn write_str(&mut self, s: &str) {
        // Length prefix keeps concatenated strings unambiguous.
        self.write_usize(s.len());
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot convenience.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Published FNV-1a test vectors — the constants are load-bearing for
    /// every pinned golden hash, so pin the algorithm itself.
    #[test]
    fn reference_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
    }
}
