//! Minimal JSON parser/serializer.
//!
//! The environment is offline (no serde in the vendored crate set), so the
//! manifest/config/report plumbing uses this hand-rolled implementation.
//! It supports the full JSON grammar we emit and consume: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fallible field access with a readable path in the error.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
        Json::Arr(it.into_iter().collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let numeric =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if numeric(c)) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\\ é""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\ é"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn nested_deep() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
