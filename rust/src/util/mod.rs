//! Substrate utilities built from scratch for the offline environment:
//! JSON, CLI parsing, deterministic RNG, a thread pool, timing statistics,
//! and a mini property-testing harness.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
