//! Mini property-testing harness (no proptest in the offline crate set).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs, runs the property,
//! and on failure greedily shrinks using the input's `Shrink` implementation
//! before panicking with the minimal counterexample. Coordinator invariants
//! (planner feasibility, queue ordering, memory accounting) use this.

use crate::util::rng::Rng;
use std::fmt::Debug;

pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller inputs (empty when minimal).
    fn shrink(&self) -> Vec<Self> {
        vec![]
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if self.abs() > 1e-9 {
            vec![self / 2.0, 0.0]
        } else {
            vec![]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if self.is_empty() {
            return out;
        }
        // remove halves, remove single elements, shrink single elements
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for s in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cases` random inputs from `gen`; shrink on failure.
pub fn check<T, G, P>(cases: usize, seed: u64, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 input: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Debug, P: Fn(&T) -> Result<(), String>>(
    mut input: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    let mut budget = 500;
    'outer: while budget > 0 {
        for cand in input.shrink() {
            budget -= 1;
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(200, 1, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(200, 2, |r| r.below(100), |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        });
    }

    #[test]
    fn shrinks_to_small_counterexample() {
        // Property: all vectors have length < 3. Shrinker should find a
        // counterexample of exactly length 3.
        let result = std::panic::catch_unwind(|| {
            check(
                50,
                3,
                |r| (0..r.usize_below(20)).map(|_| r.below(5)).collect::<Vec<u64>>(),
                |v| {
                    if v.len() < 3 {
                        Ok(())
                    } else {
                        Err("too long".into())
                    }
                },
            );
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        // The minimal failing vector has exactly 3 elements.
        assert!(msg.contains("input: ["), "{msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4usize, 2u64);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|(a, _)| *a < 4));
        assert!(shrunk.iter().any(|(_, b)| *b < 2));
    }
}
