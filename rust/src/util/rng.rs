//! Deterministic PRNG (SplitMix64 + xoshiro256**) — no `rand` crate offline.
//!
//! Used by the task generators (hot path), the property-test harness and the
//! simulators. Determinism across runs matters: every experiment records its
//! seed in the report.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut word = || splitmix64(&mut sm);
        Rng { s: [word(), word(), word(), word()] }
    }

    /// Derive an independent stream (for per-job / per-adapter generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.usize_below(v.len())]
    }

    /// Standard normal via Box-Muller (for synthetic tensors in tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn forks_are_independent() {
        let mut r = Rng::new(3);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
