//! Timing statistics for the bench harness and engine metrics.

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

/// Summarize a sample (seconds, or any unit). Percentiles by nearest-rank.
pub fn summarize(samples: &[f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let pct = |p: f64| v[(((n as f64) * p).ceil() as usize).clamp(1, n) - 1];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: v[0],
        p50: pct(0.50),
        p90: pct(0.90),
        p99: pct(0.99),
        max: v[n - 1],
    }
}

/// Online mean/variance (Welford) — allocation-free for the hot loop.
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [0.3, 1.7, 2.9, -4.0, 8.1, 0.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = summarize(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert_eq!(o.min, s.min);
        assert_eq!(o.max, s.max);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-5).ends_with("µs"));
        assert!(fmt_secs(2e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(400.0).ends_with("min"));
    }
}
