//! Minimal worker pool over std threads + mpsc (no tokio offline).
//!
//! The execution engine uses one logical worker per fine-tuning job slot.
//! Jobs are boxed closures; `join` drains outstanding work. This is
//! deliberately simple — the engine's concurrency unit is a whole
//! fine-tuning job (seconds+), so per-task overhead is irrelevant.
//!
//! [`ThreadPool::scoped`] adds a borrowed-closure entry point on the same
//! workers, and [`global`] exposes one process-wide pool: together they
//! let the reference backend's per-adapter `dA`/`dB` gradient reductions
//! fan out across **persistent** workers (no per-region thread spawns —
//! the remaining Amdahl floor the ROADMAP names) while each adapter's
//! reduction stays sequential on one worker, so results are bitwise
//! invariant to the worker count.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Pool-id generator (0 is "not a pool worker").
static NEXT_POOL_ID: AtomicUsize = AtomicUsize::new(1);

thread_local! {
    /// Id of the [`ThreadPool`] this thread is a worker of, if any. Lets
    /// [`ThreadPool::scoped`] detect re-entrant dispatch onto its own pool
    /// (which would deadlock: a worker parked on the latch cannot drain
    /// the very queue its sub-tasks sit in) and degrade to inline serial
    /// execution — bitwise identical, only the wall clock differs.
    static ACTIVE_POOL: Cell<usize> = const { Cell::new(0) };
}

/// The process-wide pool [`ThreadPool::scoped`] callers share. Sized to
/// the machine (at least 4 workers) — `scoped` batches of any size run
/// fine on fewer workers, tasks simply queue.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n.max(4))
    })
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    id: usize,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let id = NEXT_POOL_ID.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("plora-worker-{i}"))
                    .spawn(move || {
                        ACTIVE_POOL.with(|p| p.set(id));
                        loop {
                            let task = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match task {
                                Ok(t) => {
                                    t();
                                    inflight.fetch_sub(1, Ordering::SeqCst);
                                }
                                Err(_) => break, // sender dropped: shut down
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, inflight, id }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool alive");
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with sleep) until all submitted tasks completed.
    pub fn join(&self) {
        while self.inflight() > 0 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// Run borrowed closures on the pool's persistent workers and block
    /// until **all of them** finished — a scoped-threads equivalent
    /// without per-call spawns. The last task runs inline on the calling
    /// thread (it would only block otherwise). Panics in tasks are caught
    /// on the worker and re-raised here after every task completed, so
    /// the borrowed data the closures captured is never observed while a
    /// sibling still runs.
    ///
    /// Safety of the internal lifetime erasure: the closures are only
    /// executed between this call's entry and its return (the completion
    /// latch is waited on before returning on every path), so the `'a`
    /// borrows they capture outlive every execution.
    pub fn scoped<'a>(&self, mut tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        // Re-entrant dispatch onto our own pool would deadlock (the
        // calling worker parks on the latch and cannot drain the queue):
        // run inline instead — every scoped batch is bitwise
        // order-invariant by contract, only wall time changes.
        if ACTIVE_POOL.with(|p| p.get()) == self.id {
            for t in tasks {
                t();
            }
            return;
        }
        let Some(last) = tasks.pop() else { return };
        if tasks.is_empty() {
            last();
            return;
        }
        struct Latch {
            left: Mutex<usize>,
            cv: Condvar,
            panicked: AtomicUsize,
        }
        let latch = Arc::new(Latch {
            left: Mutex::new(tasks.len()),
            cv: Condvar::new(),
            panicked: AtomicUsize::new(0),
        });
        for t in tasks {
            // Erase the borrow lifetime: execution is fenced by the latch
            // below, see the doc comment.
            let t = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(t)
            };
            let latch = Arc::clone(&latch);
            self.spawn(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(t));
                if r.is_err() {
                    latch.panicked.fetch_add(1, Ordering::SeqCst);
                }
                let mut left = latch.left.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    latch.cv.notify_all();
                }
            });
        }
        let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(last));
        let mut left = latch.left.lock().unwrap();
        while *left > 0 {
            left = latch.cv.wait(left).unwrap();
        }
        drop(left);
        if inline.is_err() || latch.panicked.load(Ordering::SeqCst) > 0 {
            panic!("threadpool: scoped task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_waits_for_slow_tasks() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.spawn(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    /// `scoped` runs borrowed closures to completion before returning —
    /// every chunk of a stack-owned buffer is written, on any pool size
    /// (including fewer workers than tasks).
    #[test]
    fn scoped_completes_borrowed_tasks() {
        for workers in [1usize, 2, 8] {
            let pool = ThreadPool::new(workers);
            let mut data = vec![0u64; 12];
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(3)
                .enumerate()
                .map(|(i, c)| {
                    let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        for (j, x) in c.iter_mut().enumerate() {
                            *x = (i * 3 + j) as u64 + 1;
                        }
                    });
                    f
                })
                .collect();
            pool.scoped(tasks);
            assert_eq!(data, (1..=12).collect::<Vec<u64>>());
        }
        // Empty and single-task batches are fine (inline fast paths).
        let pool = ThreadPool::new(2);
        pool.scoped(vec![]);
        let mut hit = false;
        pool.scoped(vec![Box::new(|| hit = true)]);
        assert!(hit);
        // The global pool exists and is reusable.
        let mut a = 0u32;
        global().scoped(vec![Box::new(|| a += 1), Box::new(|| {})]);
        assert_eq!(a, 1);
    }

    /// Dispatching a scoped batch from one of the pool's own workers
    /// (nested use) must not deadlock: the guard runs it inline.
    #[test]
    fn nested_scoped_on_own_pool_runs_inline() {
        let pool = ThreadPool::new(2);
        let mut out = vec![0u8; 4];
        {
            let (a, b) = out.split_at_mut(2);
            let p = &pool;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(move || {
                    // This task lands on a worker; its nested dispatch
                    // onto the same pool must fall back to inline.
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = a
                        .iter_mut()
                        .map(|x| {
                            let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || *x = 1);
                            f
                        })
                        .collect();
                    p.scoped(inner);
                }),
                Box::new(move || {
                    for x in b.iter_mut() {
                        *x = 2;
                    }
                }),
            ];
            pool.scoped(tasks);
        }
        assert_eq!(out, vec![1, 1, 2, 2]);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| {});
        pool.join();
        drop(pool); // must not hang
    }
}
