//! Minimal worker pool over std threads + mpsc (no tokio offline).
//!
//! The execution engine uses one logical worker per fine-tuning job slot.
//! Jobs are boxed closures; `join` drains outstanding work. This is
//! deliberately simple — the engine's concurrency unit is a whole
//! fine-tuning job (seconds+), so per-task overhead is irrelevant.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Task = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("plora-worker-{i}"))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match task {
                            Ok(t) => {
                                t();
                                inflight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, inflight }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool alive");
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with sleep) until all submitted tasks completed.
    pub fn join(&self) {
        while self.inflight() > 0 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_waits_for_slow_tasks() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.spawn(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| {});
        pool.join();
        drop(pool); // must not hang
    }
}
