//! End-to-end daemon tests: spawn the real `plora` binary in
//! `serve --daemon` mode, drive it over the HTTP control plane, and check
//! the three service-level guarantees:
//!
//! 1. **Crash-exactness** — `kill -9` mid-job, restart on the same state
//!    directory, and the combined `SessionDigest` is bit-identical to an
//!    uninterrupted run's.
//! 2. **Weighted fair share** — two tenants with 4:1 weights get
//!    correspondingly ordered admission priorities, and the low-weight
//!    tenant still completes.
//! 3. **Cancel** — a cancelled job ends `cancelled` and never overrides
//!    to `done`, while its neighbours finish normally.
//!
//! The daemon synthesizes its runtime when `artifacts/` is absent, so
//! these tests run everywhere the unit tests do.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use plora::daemon::http::request;
use plora::util::json::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_plora")
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("plora-daemon-test-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A spawned daemon process; killed on drop so a failing assertion never
/// leaks a child.
struct DaemonProc {
    child: Child,
    addr: String,
}

impl Drop for DaemonProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Start `plora serve --daemon` on `dir` (ephemeral port) and wait for it
/// to publish its address.
fn start_daemon(dir: &Path, steps: usize) -> DaemonProc {
    let addr_file = dir.join("daemon.addr");
    let _ = std::fs::remove_file(&addr_file); // stale after a SIGKILL
    let child = Command::new(bin())
        .args([
            "serve",
            "--daemon",
            "--dir",
            dir.to_str().unwrap(),
            "--port",
            "0",
            "--model",
            "nano",
            "--gpus",
            "2",
            "--steps",
            &steps.to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn daemon");
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&addr_file) {
            if !s.trim().is_empty() {
                break s.trim().to_string();
            }
        }
        assert!(Instant::now() < deadline, "daemon never published {}", addr_file.display());
        std::thread::sleep(Duration::from_millis(20));
    };
    DaemonProc { child, addr }
}

fn submit(addr: &str, tenant: &str, weight: f64, tasks: &[&str]) -> Json {
    let adapters = Json::arr(tasks.iter().map(|t| {
        Json::obj(vec![
            ("task", Json::str(*t)),
            ("rank", Json::num(8.0)),
            ("batch", Json::num(1.0)),
            ("lr", Json::num(2e-3)),
        ])
    }));
    let body = Json::obj(vec![
        ("tenant", Json::str(tenant)),
        ("weight", Json::num(weight)),
        ("adapters", adapters),
    ]);
    let (st, resp) = request(addr, "POST", "/v1/jobs", Some(&body)).expect("submit");
    assert_eq!(st, 200, "submit failed: {resp}");
    resp
}

fn jobs(addr: &str) -> Vec<Json> {
    let (st, resp) = request(addr, "GET", "/v1/jobs", None).expect("list");
    assert_eq!(st, 200);
    resp.field("jobs").unwrap().as_arr().unwrap().to_vec()
}

fn state_of(v: &Json) -> String {
    v.field("state").unwrap().as_str().unwrap().to_string()
}

/// Poll until every job is in a terminal state; panic on `failed`.
fn wait_all_terminal(addr: &str, expect_jobs: usize) -> Vec<Json> {
    let deadline = Instant::now() + Duration::from_secs(180);
    loop {
        let js = jobs(addr);
        if js.len() >= expect_jobs {
            for j in &js {
                assert_ne!(
                    state_of(j),
                    "failed",
                    "job failed: {j}",
                );
            }
            if js.iter().all(|j| matches!(state_of(j).as_str(), "done" | "cancelled")) {
                return js;
            }
        }
        assert!(Instant::now() < deadline, "jobs never finished: {:?}", jobs(addr));
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn digest_text(addr: &str) -> String {
    let (st, resp) = request(addr, "GET", "/v1/digest", None).expect("digest");
    assert_eq!(st, 200);
    let mut s = String::new();
    resp.write(&mut s);
    s
}

fn shutdown(mut d: DaemonProc) {
    let _ = request(&d.addr, "POST", "/v1/shutdown", None);
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match d.child.try_wait().expect("try_wait") {
            Some(status) => {
                assert!(status.success(), "daemon exited with {status}");
                break;
            }
            None => {
                assert!(Instant::now() < deadline, "daemon never drained after shutdown");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
    // Drop still runs (kill is a no-op on a reaped child).
}

/// `kill -9` mid-flight + restart must reproduce the uninterrupted run's
/// digest bit-for-bit (ISSUE 7 acceptance).
#[test]
fn sigkill_recovery_is_bit_exact() {
    let tasks: [&[&str]; 2] = [&["modadd", "copy"], &["parity", "needle"]];

    // Reference: uninterrupted run.
    let dir_a = fresh_dir("ref");
    let a = start_daemon(&dir_a, 32);
    for t in tasks {
        submit(&a.addr, "acme", 1.0, t);
    }
    wait_all_terminal(&a.addr, 2);
    let want = digest_text(&a.addr);
    assert!(want.contains("fingerprint"), "digest missing fingerprint: {want}");
    shutdown(a);

    // Crash run: same submissions, SIGKILL once training is in flight.
    let dir_b = fresh_dir("crash");
    let mut b = start_daemon(&dir_b, 32);
    for t in tasks {
        submit(&b.addr, "acme", 1.0, t);
    }
    // Long-poll until the session has emitted at least one event
    // (job_started), so the kill lands mid-job, not pre-dispatch.
    let (st, ev) =
        request(&b.addr, "GET", "/v1/events?since=0&wait=30000", None).expect("events");
    assert_eq!(st, 200);
    assert!(
        ev.field("next").unwrap().as_usize().unwrap() > 0,
        "no events before kill: {ev}"
    );
    b.child.kill().expect("SIGKILL"); // Child::kill is SIGKILL on unix
    let _ = b.child.wait();
    drop(b);

    // Restart on the same directory: journal replay + checkpoint resume.
    let b2 = start_daemon(&dir_b, 32);
    wait_all_terminal(&b2.addr, 2);
    let got = digest_text(&b2.addr);
    assert_eq!(
        got, want,
        "post-crash digest differs from uninterrupted run (crash-exactness violated)"
    );
    shutdown(b2);
}

/// Two tenants, weights 4:1: the heavy tenant's jobs are admitted at
/// strictly better priorities than the light tenant's backlog, the
/// priority ordering within each tenant is monotone, and — fair share,
/// not starvation — every job of both tenants completes. Also checks
/// idempotent re-submit by token.
#[test]
fn weighted_fair_share_across_tenants() {
    let dir = fresh_dir("fairshare");
    let d = start_daemon(&dir, 32);
    let prio = |r: &Json| r.field("priority").unwrap().as_f64().unwrap() as i64;

    let h1 = submit(&d.addr, "heavy", 4.0, &["modadd"]);
    let h2 = submit(&d.addr, "heavy", 4.0, &["copy"]);
    let l1 = submit(&d.addr, "light", 1.0, &["parity"]);
    let l2 = submit(&d.addr, "light", 1.0, &["needle"]);
    let h3 = submit(&d.addr, "heavy", 4.0, &["modadd"]);

    // Weight-4 backlog advances virtual time 4x slower: heavy's second
    // job still outranks light's second job, deterministically.
    assert!(
        prio(&h2) > prio(&l2),
        "heavy backlog must outrank light backlog: h2 {} vs l2 {}",
        prio(&h2),
        prio(&l2)
    );
    // Within a tenant, tags (so priorities) are strictly monotone.
    assert!(prio(&h1) > prio(&h2) && prio(&h2) > prio(&h3), "heavy priorities not monotone");
    assert!(prio(&l1) > prio(&l2), "light priorities not monotone");

    // Idempotency: re-sending a token re-acks the original admission.
    let token = h1.field("token").unwrap().as_str().unwrap().to_string();
    let body = Json::obj(vec![
        ("tenant", Json::str("heavy")),
        ("token", Json::str(token)),
        ("adapters", Json::arr([Json::obj(vec![("task", Json::str("modadd"))])])),
    ]);
    let (st, re) = request(&d.addr, "POST", "/v1/jobs", Some(&body)).expect("re-submit");
    assert_eq!(st, 200);
    assert_eq!(re.field("deduped").unwrap().as_bool(), Some(true));
    assert_eq!(
        re.field("job").unwrap().as_usize(),
        h1.field("job").unwrap().as_usize(),
        "token re-ack must return the original job"
    );

    // Fair share is not starvation: the light tenant completes too.
    let js = wait_all_terminal(&d.addr, 5);
    assert_eq!(js.len(), 5, "dedup must not have created a sixth job");
    assert!(js.iter().all(|j| state_of(j) == "done"), "all jobs complete: {js:?}");
    shutdown(d);
}

/// Cancelling a queued job sticks: it reports `cancelled` (never flipping
/// to `done`), and the rest of the queue completes.
#[test]
fn cancel_sticks_and_neighbours_complete() {
    let dir = fresh_dir("cancel");
    let d = start_daemon(&dir, 64);
    submit(&d.addr, "t", 1.0, &["modadd"]);
    submit(&d.addr, "t", 1.0, &["copy"]);
    // Two GPUs busy: the third job is queued; cancel it immediately.
    let c = submit(&d.addr, "t", 1.0, &["parity"]);
    let id = c.field("job").unwrap().as_usize().unwrap();
    let (st, resp) =
        request(&d.addr, "POST", &format!("/v1/jobs/{id}/cancel"), None).expect("cancel");
    assert_eq!(st, 200, "cancel failed: {resp}");
    // A second cancel of the same job is a 409, not a double-journal.
    let (st2, _) =
        request(&d.addr, "POST", &format!("/v1/jobs/{id}/cancel"), None).expect("re-cancel");
    assert_eq!(st2, 409);

    let js = wait_all_terminal(&d.addr, 3);
    let cancelled: Vec<_> = js.iter().filter(|j| state_of(j) == "cancelled").collect();
    let done: Vec<_> = js.iter().filter(|j| state_of(j) == "done").collect();
    assert_eq!(cancelled.len(), 1, "exactly the cancelled job: {js:?}");
    assert_eq!(cancelled[0].field("job").unwrap().as_usize(), Some(id));
    assert_eq!(done.len(), 2, "neighbours complete: {js:?}");
    shutdown(d);
}
