//! Golden-hash trajectory tests: a fixed nano workload is trained once per
//! `(seed, policy, d)` cell and reduced to a [`SessionDigest`]
//! fingerprint.
//!
//! Two assertions, with different portability:
//!
//! 1. **Cross-cell invariance (always on, portable):** for a given seed,
//!    every `(policy, d)` cell must produce the *same* digest — scheduling
//!    policy and data-parallel degree may move the timeline, never the
//!    trajectory. A mismatch fails with the digest's field-level diff.
//!    The cells submit depth-unplanned jobs (`s = 0`), so CI's
//!    `PLORA_STAGES=2` leg re-runs the whole grid through the stage
//!    pipeline and re-checks the same pins — depth is trajectory-inert
//!    too.
//! 2. **Golden pins (machine-local):** the per-cell fingerprints are
//!    compared against `tests/golden/nano_trajectories.json` *when that
//!    file is pinned*. Absolute bit patterns depend on the platform's libm
//!    (`exp`/`ln` are not cross-platform bit-stable), so the committed
//!    file ships `"status": "unpinned"` and CI pins it on the runner first
//!    (`PLORA_GOLDEN=pin cargo test -q --test golden`), then re-runs the
//!    suite to prove the pins hold — any later nondeterminism on the same
//!    machine is a hard failure.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{pool, AdapterSpec};
use plora::costmodel::{ExecMode, Pack, TrainBudget};
use plora::planner::PlannedJob;
use plora::runtime::Runtime;
use plora::session::{Policy, Session};
use plora::trace::SessionDigest;
use plora::train::TrainOptions;
use plora::util::json::Json;

const SEEDS: [u64; 2] = [17, 23];
const POLICIES: [Policy; 3] = [Policy::Fifo, Policy::Priority, Policy::PreemptLowest];
const DEVICE_COUNTS: [usize; 2] = [1, 2];

fn runtime() -> Arc<Runtime> {
    // Point at a directory with no artifacts: synthesizes everything.
    Arc::new(Runtime::load(&std::env::temp_dir().join("plora-no-artifacts")).unwrap())
}

fn spec(task: &str, rank: usize, batch: usize, lr: f64) -> AdapterSpec {
    AdapterSpec { lr, batch, rank, alpha_ratio: 1.0, task: task.into() }
}

fn policy_tag(p: Policy) -> &'static str {
    match p {
        Policy::Fifo => "fifo",
        Policy::Priority => "priority",
        Policy::PreemptLowest => "preempt",
    }
}

fn cell_label(seed: u64, policy: Policy, d: usize) -> String {
    format!("s{seed}_{}_d{d}", policy_tag(policy))
}

/// Train the fixed golden workload under one cell's settings: two jobs,
/// three adapters (mixed batch sizes), sharded `d` ways, on a 2-device
/// pool.
fn run_cell(rt: &Arc<Runtime>, seed: u64, policy: Policy, d: usize) -> SessionDigest {
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2), "nano");
    session.options = TrainOptions {
        budget: TrainBudget { dataset: 8, epochs: 1 },
        eval_batches: 1,
        seed,
        log_every: 2,
    };
    session.set_policy(policy);
    let jobs = [
        (
            PlannedJob {
                id: 0,
                pack: Pack::new(vec![
                    spec("modadd", 8, 1, 2e-3).with_id(0),
                    spec("parity", 8, 2, 2e-3).with_id(1),
                ]),
                d,
                s: 0,
                mode: ExecMode::Packed,
            },
            2,
        ),
        (
            PlannedJob {
                id: 1,
                pack: Pack::new(vec![spec("copy", 8, 1, 2e-3).with_id(2)]),
                d,
                s: 0,
                mode: ExecMode::Packed,
            },
            1,
        ),
    ];
    for (job, prio) in jobs {
        session.submit_planned_at(job, prio).unwrap();
    }
    SessionDigest::of(&session.drain().unwrap())
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/nano_trajectories.json")
}

fn write_golden(cells: &BTreeMap<String, u64>) {
    let mut obj = BTreeMap::new();
    obj.insert("schema".to_string(), Json::num(1.0));
    obj.insert("status".to_string(), Json::str("pinned"));
    obj.insert("model".to_string(), Json::str("nano"));
    let mut jcells = BTreeMap::new();
    for (label, fp) in cells {
        jcells.insert(label.clone(), Json::str(format!("{fp:016x}")));
    }
    obj.insert("cells".to_string(), Json::Obj(jcells));
    let mut out = String::new();
    Json::Obj(obj).write(&mut out);
    out.push('\n');
    std::fs::write(golden_path(), out).unwrap();
}

/// One test runs the whole grid (each cell is a real training session, so
/// computing it once and asserting both properties keeps the suite fast).
#[test]
fn golden_trajectories_per_seed_policy_devices() {
    let rt = runtime();
    let mut cells: BTreeMap<String, u64> = BTreeMap::new();
    for seed in SEEDS {
        // (label, digest) of the seed's first cell — the invariance anchor.
        let mut anchor: Option<(String, SessionDigest)> = None;
        for policy in POLICIES {
            for d in DEVICE_COUNTS {
                let label = cell_label(seed, policy, d);
                let digest = run_cell(&rt, seed, policy, d);
                assert_eq!(digest.adapters.len(), 3, "{label}: adapter count");
                match &anchor {
                    None => anchor = Some((label.clone(), digest.clone())),
                    Some((alabel, adigest)) => {
                        let diff = adigest.diff(&digest);
                        assert!(
                            diff.is_empty(),
                            "seed {seed}: trajectory depends on scheduling — \
                             {label} diverged from {alabel}:\n{diff}"
                        );
                    }
                }
                cells.insert(label, digest.fingerprint());
            }
        }
    }

    if std::env::var("PLORA_GOLDEN").as_deref() == Ok("pin") {
        write_golden(&cells);
        println!("pinned {} cells to {}", cells.len(), golden_path().display());
        return;
    }

    let text = std::fs::read_to_string(golden_path()).unwrap();
    let golden = Json::parse(&text).unwrap();
    assert_eq!(golden.field("schema").unwrap().as_u64(), Some(1), "golden schema");
    if golden.field("status").unwrap().as_str() != Some("pinned") {
        // Committed state: absolute hashes are machine-specific, so the
        // repo ships no pins. CI pins locally and re-checks (see module
        // docs); the cross-cell invariance above already ran either way.
        println!("golden file unpinned — skipping absolute-hash comparison");
        return;
    }
    let pinned = golden.field("cells").unwrap().as_obj().unwrap();
    let mut mismatches = vec![];
    for (label, fp) in &cells {
        let want = pinned
            .get(label)
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok());
        match want {
            Some(w) if w == *fp => {}
            Some(w) => mismatches.push(format!("  {label}: pinned {w:016x}, got {fp:016x}")),
            None => mismatches.push(format!("  {label}: no pin recorded, got {fp:016x}")),
        }
    }
    assert!(
        mismatches.is_empty(),
        "golden trajectory fingerprints diverged from the pinned file \
         ({}).\nRe-pin with PLORA_GOLDEN=pin if the change is intended:\n{}",
        golden_path().display(),
        mismatches.join("\n")
    );
}
