//! Integration tests across modules: planner → engine → runtime → train →
//! checkpoint pool, and planner → simulator consistency. These exercise the
//! real PJRT path on the `nano` TinyLM (skipped if artifacts are missing).

use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{geometry, pool, LoraConfig, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::engine::{CheckpointPool, Engine};
use plora::planner::{min_gpu_plan, JobPlanner};
use plora::runtime::Runtime;
use plora::sim::{SimOptions, Simulator};
use plora::train::{run_pack_full, TrainOptions};

fn runtime() -> Option<Arc<Runtime>> {
    let dir = Runtime::default_dir();
    dir.join("manifest.json").exists().then(|| Arc::new(Runtime::load(&dir).unwrap()))
}

fn cfg(id: usize, task: &str, rank: usize, bs: usize) -> LoraConfig {
    LoraConfig { id, lr: 2e-3, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
}

/// Full pipeline: plan a small space with the PLoRA planner against the
/// live profile, execute the queue on the engine (concurrent PJRT jobs),
/// save checkpoints, reload one, and check invariants along the way.
#[test]
fn plan_execute_checkpoint_roundtrip() {
    let Some(rt) = runtime() else { return };
    let mi = rt.manifest.model("nano").unwrap().clone();
    let geom = geometry::tiny_geom(
        "nano", mi.n_layers, mi.d_model, mi.d_ff, mi.n_heads, mi.vocab, mi.seq,
    );
    let mut cm = CostModel::new(&geom, &pool::CPU_SIM);
    cm.charge_padding = true;
    cm.buckets = Some(rt.manifest.train_buckets("nano"));

    let tasks = ["modadd", "copy", "parity", "needle"];
    let configs: Vec<LoraConfig> =
        (0..6).map(|i| cfg(i, tasks[i % 4], 8, 1 + (i % 2))).collect();

    let mut planner = JobPlanner::new(cm, 2);
    planner.budget = TrainBudget { dataset: 8, epochs: 1 };
    let plan = planner.plan(&configs).unwrap();
    assert_eq!(plan.total_configs(), 6);

    let ckpt_dir = std::env::temp_dir().join("plora_it_ckpts");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut engine = Engine::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2));
    engine.options.budget = planner.budget;
    engine.options.eval_batches = 1;
    engine.options.log_every = 0;
    engine.checkpoints = Some(CheckpointPool::new(&ckpt_dir, rt.clone()).unwrap());

    let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
    let report = engine.run("nano", &queue).unwrap();
    assert_eq!(report.total_adapters(), 6);
    assert!(report.makespan > 0.0);

    // All six adapters checkpointed, tensors reload at true rank.
    let pool_ref = engine.checkpoints.as_ref().unwrap();
    assert_eq!(pool_ref.list("nano"), vec![0, 1, 2, 3, 4, 5]);
    let t = pool_ref.load("nano", 3).unwrap();
    assert_eq!(t.len(), 14);
    let (name, aq) = t.iter().find(|(n, _)| n == "a_q").unwrap();
    assert_eq!(name, "a_q");
    assert_eq!(aq.shape, vec![mi.n_layers, mi.d_model, 8]);
    let meta = pool_ref.load_meta("nano", 3).unwrap();
    assert_eq!(meta.field("task").unwrap().as_str().unwrap(), tasks[3]);
}

/// A reloaded checkpoint reproduces the packed state's slice exactly.
#[test]
fn checkpoint_tensors_match_state_slices() {
    let Some(rt) = runtime() else { return };
    let configs = vec![cfg(0, "modadd", 8, 1), cfg(1, "needle", 8, 1)];
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 4, epochs: 1 },
        eval_batches: 1,
        seed: 5,
        log_every: 0,
    };
    let (_, state) = run_pack_full(&rt, "nano", &configs, &opts).unwrap();
    let dir = std::env::temp_dir().join("plora_it_slice");
    let _ = std::fs::remove_dir_all(&dir);
    let pool_ = CheckpointPool::new(&dir, rt.clone()).unwrap();
    pool_.save_state("nano", &state, &[(1, 1, 8)]).unwrap();
    let loaded = pool_.load("nano", 1).unwrap();
    let direct = state.extract_adapter(1, 8).unwrap();
    for ((ln, lt), (dn, dt)) in loaded.iter().zip(&direct) {
        assert_eq!(ln, dn);
        assert_eq!(lt.shape, dt.shape);
        assert_eq!(lt.as_f32().unwrap(), dt.as_f32().unwrap());
    }
}

/// Determinism: the same seed reproduces the same training trajectory.
#[test]
fn training_is_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let configs = vec![cfg(0, "parity", 8, 1)];
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 6, epochs: 1 },
        eval_batches: 1,
        seed: 99,
        log_every: 1,
    };
    let a = plora::train::run_pack(&rt, "nano", &configs, &opts).unwrap();
    let b = plora::train::run_pack(&rt, "nano", &configs, &opts).unwrap();
    assert_eq!(a.adapters[0].final_loss, b.adapters[0].final_loss);
    assert_eq!(a.adapters[0].eval_acc, b.adapters[0].eval_acc);
    let mut opts2 = opts.clone();
    opts2.seed = 100;
    let c = plora::train::run_pack(&rt, "nano", &configs, &opts2).unwrap();
    assert_ne!(a.adapters[0].final_loss, c.adapters[0].final_loss);
}

/// Packing isolation (§3.2 "computation of each adapter is identical to
/// single-adapter fine-tuning"): an adapter's trajectory must not depend
/// on *which* other adapters are packed with it. We train config X alone
/// and packed next to a very different neighbour and compare eval metrics.
#[test]
fn packed_adapter_matches_solo_training() {
    let Some(rt) = runtime() else { return };
    let x = cfg(0, "modadd", 8, 1);
    let noisy_neighbor = LoraConfig {
        id: 1,
        lr: 8e-3,
        batch: 2,
        rank: 8,
        alpha_ratio: 2.0,
        task: "copy".into(),
    };
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 12, epochs: 1 },
        eval_batches: 2,
        seed: 31,
        log_every: 0,
    };
    let solo = plora::train::run_pack(&rt, "nano", &[x.clone()], &opts).unwrap();
    let packed = plora::train::run_pack(&rt, "nano", &[x, noisy_neighbor], &opts).unwrap();
    let (s, p) = (&solo.adapters[0], &packed.adapters[0]);
    // Per-adapter init/data/eval streams are keyed by (seed, adapter id),
    // so the trajectory is identical across bucket shapes — not merely
    // statistically indistinguishable.
    assert_eq!(s.base_acc, p.base_acc, "frozen-base eval must be identical");
    assert!(
        (s.eval_loss - p.eval_loss).abs() <= 1e-5 * s.eval_loss.abs().max(1.0),
        "solo {} vs packed {} eval loss diverged",
        s.eval_loss,
        p.eval_loss
    );
    assert!(
        (s.final_loss - p.final_loss).abs() <= 1e-5 * s.final_loss.abs().max(1.0),
        "solo {} vs packed {} train loss diverged",
        s.final_loss,
        p.final_loss
    );
}

/// Planner predictions and the DES agree on Min-GPU queues too.
#[test]
fn baseline_plan_matches_simulated_timeline() {
    let cm = CostModel::new(geometry::geom("qwen2.5-7b").unwrap(), &pool::A100_40G);
    let budget = TrainBudget::default();
    let grid = SearchSpace::default().grid("t");
    let plan = min_gpu_plan(&cm, &budget, 8, &grid).unwrap();
    let sim = Simulator { cm, budget, gpus: 8 };
    let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
    let res = sim.run_queue(&queue, &SimOptions::default());
    assert!((res.makespan - plan.makespan).abs() / plan.makespan < 1e-6);
    assert_eq!(res.jobs.len(), plan.jobs.len());
}

/// The engine honours FIFO queue order under contention: with one device,
/// outcomes complete in queue order.
#[test]
fn engine_fifo_with_single_device() {
    let Some(rt) = runtime() else { return };
    let mut engine = Engine::new(rt, ResourceMonitor::new(&pool::CPU_SIM, 1));
    engine.options.budget = TrainBudget { dataset: 3, epochs: 1 };
    engine.options.eval_batches = 1;
    engine.options.log_every = 0;
    let queue: Vec<_> = (0..3)
        .map(|i| plora::planner::PlannedJob {
            id: i,
            pack: plora::costmodel::Pack::new(vec![cfg(i, "copy", 8, 1)]),
            d: 1,
            s: 0,
            mode: plora::costmodel::ExecMode::Packed,
        })
        .collect();
    let report = engine.run("nano", &queue).unwrap();
    for w in report.outcomes.windows(2) {
        assert!(w[0].start <= w[1].start + 1e-9, "FIFO violated");
    }
}
