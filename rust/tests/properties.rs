//! Property-based tests on coordinator invariants (planner, packing,
//! scheduling, cost model) using the in-tree mini property harness
//! (`plora::util::prop`) — random search spaces, shrunk counterexamples.

use plora::config::geometry::geom;
use plora::config::pool::A100_40G;
use plora::config::LoraConfig;
use plora::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use plora::planner::{min_gpu_plan, JobPlanner, PackProblem};
use plora::sim::{SimOptions, Simulator};
use plora::util::prop::{check, Shrink};
use plora::util::rng::Rng;

/// A random LoRA configuration encoded as (rank_idx, bs_idx, lr_idx, alpha_idx).
#[derive(Debug, Clone)]
struct Space(Vec<(usize, usize)>); // (rank, batch)

impl Shrink for Space {
    fn shrink(&self) -> Vec<Self> {
        self.0.shrink().into_iter().filter(|v| !v.is_empty()).map(Space).collect()
    }
}

fn gen_space(rng: &mut Rng, max_n: usize) -> Space {
    let ranks = [8usize, 16, 32, 64, 128];
    let batches = [1usize, 2, 4, 8];
    let n = 1 + rng.usize_below(max_n);
    Space(
        (0..n)
            .map(|_| (*rng.choice(&ranks), *rng.choice(&batches)))
            .collect(),
    )
}

fn configs_of(s: &Space) -> Vec<LoraConfig> {
    s.0.iter()
        .enumerate()
        .map(|(id, &(rank, batch))| LoraConfig {
            id,
            lr: 1e-4,
            batch,
            rank,
            alpha_ratio: 1.0,
            task: "t".into(),
        })
        .collect()
}

/// Every random space is fully scheduled: each config exactly once, every
/// pack memory-feasible at its degree, no GPU oversubscription at any time,
/// and the makespan respects the certified lower bound.
#[test]
fn planner_schedules_every_space_feasibly() {
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &A100_40G);
    check(
        12,
        71,
        |rng| gen_space(rng, 24),
        |s| {
            let configs = configs_of(s);
            let mut planner = JobPlanner::new(cm.clone(), 8);
            planner.budget = TrainBudget { dataset: 64, epochs: 1 };
            let plan = planner.plan(&configs).map_err(|e| e.to_string())?;
            // exactly-once
            let mut ids: Vec<usize> =
                plan.jobs.iter().flat_map(|j| j.job.pack.configs.iter().map(|c| c.id)).collect();
            ids.sort();
            let want: Vec<usize> = (0..configs.len()).collect();
            if ids != want {
                return Err(format!("scheduled ids {ids:?} != {want:?}"));
            }
            // feasibility
            for j in &plan.jobs {
                if !cm.fits(&j.job.pack, j.job.d) {
                    return Err(format!("infeasible pack in {}", j.job.summary()));
                }
                if !j.job.d.is_power_of_two() || j.job.d > 8 {
                    return Err(format!("bad degree {}", j.job.d));
                }
            }
            // no oversubscription
            for t in plan.jobs.iter().map(|j| j.start + 1e-9) {
                let used: usize = plan
                    .jobs
                    .iter()
                    .filter(|j| j.start <= t && t < j.end)
                    .map(|j| j.job.d)
                    .sum();
                if used > 8 {
                    return Err(format!("{used} GPUs at t={t}"));
                }
            }
            // lower bound
            if plan.makespan < plan.lb_makespan - 1e-6 {
                return Err(format!(
                    "makespan {} below its lower bound {}",
                    plan.makespan, plan.lb_makespan
                ));
            }
            Ok(())
        },
    );
}

/// The ILP never returns an infeasible pack and never loses to the
/// trivial single-best-config solution.
#[test]
fn ilp_solution_feasible_and_dominates_singletons() {
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &A100_40G);
    let budget = TrainBudget::default();
    check(
        20,
        13,
        |rng| gen_space(rng, 40),
        |s| {
            let configs = configs_of(s);
            let p = PackProblem::new(&cm, 1, ExecMode::Packed, &budget);
            let Some(sol) = p.solve(&configs) else {
                return Ok(()); // nothing fits: fine
            };
            if sol.pack.n() > 0 && !cm.fits(&sol.pack, 1) {
                return Err("infeasible ILP pack".into());
            }
            let best_single = configs
                .iter()
                .filter(|c| cm.fits(&Pack::new(vec![(*c).clone()]), 1))
                .map(|c| p.objective(&Pack::new(vec![c.clone()])))
                .fold(0.0, f64::max);
            if sol.throughput + 1e-9 < best_single {
                return Err(format!(
                    "ILP {} worse than best singleton {}",
                    sol.throughput, best_single
                ));
            }
            Ok(())
        },
    );
}

/// Simulator executes any Min-GPU queue without oversubscription, and the
/// deterministic makespan is invariant to re-running.
#[test]
fn sim_is_deterministic_and_safe() {
    let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
    let budget = TrainBudget { dataset: 64, epochs: 1 };
    check(
        12,
        29,
        |rng| gen_space(rng, 32),
        |s| {
            let configs = configs_of(s);
            let plan = min_gpu_plan(&cm, &budget, 8, &configs).map_err(|e| e.to_string())?;
            let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
            let sim = Simulator { cm: cm.clone(), budget, gpus: 8 };
            let a = sim.run_queue(&queue, &SimOptions::default());
            let b = sim.run_queue(&queue, &SimOptions::default());
            if (a.makespan - b.makespan).abs() > 1e-9 {
                return Err("nondeterministic sim".into());
            }
            if a.jobs.len() != configs.len() {
                return Err("lost jobs".into());
            }
            Ok(())
        },
    );
}

/// Cost-model monotonicity: adding an adapter never reduces job time or
/// per-device memory; packing never hurts rank throughput per job time
/// versus the smaller pack trained alone at the same degree.
#[test]
fn cost_model_monotone_in_pack() {
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &A100_40G);
    let budget = TrainBudget::default();
    check(
        40,
        41,
        |rng| gen_space(rng, 12),
        |s| {
            let configs = configs_of(s);
            let pack = Pack::new(configs.clone());
            let sub = Pack::new(configs[..configs.len() - 1].to_vec());
            for mode in [ExecMode::Packed, ExecMode::Sequential] {
                let t_full = cm.job_time(&pack, 1, mode, &budget);
                let t_sub = cm.job_time(&sub, 1, mode, &budget);
                if t_full + 1e-12 < t_sub {
                    return Err(format!("job_time not monotone: {t_sub} -> {t_full} ({mode:?})"));
                }
            }
            let sh = plora::costmodel::memory::Sharding::tp(1);
            let m_full = cm.memory.job_bytes(&pack, sh, false);
            let m_sub = cm.memory.job_bytes(&sub, sh, false);
            if m_full < m_sub {
                return Err("memory not monotone".into());
            }
            Ok(())
        },
    );
}

/// The tiled and SIMD GEMM kernels and the batched-fused multi-adapter
/// driver are bit-identical to the naive reference on randomized shapes —
/// a property matrix over {tiled, simd, batched-fused} × non-tile-multiple
/// m/k/n (crossing every panel, register-block and 8-lane boundary) ×
/// zero-padded ranks (whole zero trailing columns of Aᵀ, exercising the
/// `f == 0.0` skip) × the alpha = 0 fast path — and the row-parallel
/// drivers are bit-identical at any worker count. This is the invariant
/// that lets the reference backend switch kernel implementations, fusion
/// and thread counts without perturbing any training trajectory.
#[test]
fn tiled_gemm_matches_naive_bitwise() {
    use plora::runtime::reference::gemm;
    check(
        40,
        59,
        |rng| {
            vec![
                1 + rng.usize_below(24),  // m
                1 + rng.usize_below(140), // k: crosses the 64-wide reduction panel
                1 + rng.usize_below(300), // n: crosses the 16/256-wide column tiles
                rng.usize_below(4),       // alpha selector (includes 0.0)
                rng.usize_below(1 << 16), // data seed
                1 + rng.usize_below(4),   // nb: batched adapter count
                rng.usize_below(8),       // zero-padded trailing rank columns
            ]
        },
        |v| {
            if v.len() != 7 {
                return Ok(()); // shrunk into an inconsistent shape; skip
            }
            let (m, k, n) = (v[0].max(1), v[1].max(1), v[2].max(1));
            let alpha = [1.0f32, -0.6, 0.0, 2.5][v[3] % 4];
            let mut rng = Rng::new(v[4] as u64 + 1);
            let (nb, pad) = (v[5].max(1), v[6].min(m.saturating_sub(1)));
            let mut buf = |len: usize, zero_frac: f64| -> Vec<f32> {
                (0..len)
                    .map(|_| if rng.f64() < zero_frac { 0.0 } else { rng.normal() as f32 })
                    .collect()
            };
            let a = buf(m * k, 0.3);
            let b = buf(k * n, 0.0);
            let bt = buf(n * k, 0.0);
            let at = buf(k * m, 0.3);
            let init = buf(m * n, 0.0);
            let bits = |x: &[f32]| -> Vec<u32> { x.iter().map(|f| f.to_bits()).collect() };
            type MmFn = fn(&mut [f32], &[f32], &[f32], usize, usize, usize, f32);

            let mut want = init.clone();
            gemm::naive::mm_acc(&mut want, &a, &b, m, k, n, alpha);
            for (label, f) in [
                ("tiled", gemm::tiled::mm_acc as MmFn),
                ("simd", gemm::simd::mm_acc as MmFn),
            ] {
                let mut got = init.clone();
                f(&mut got, &a, &b, m, k, n, alpha);
                if bits(&want) != bits(&got) {
                    return Err(format!("mm_acc {label} != naive at {m}x{k}x{n} alpha {alpha}"));
                }
            }
            let mut par = init.clone();
            gemm::mm_acc_par(&mut par, &a, &b, m, k, n, alpha, 4);
            if bits(&want) != bits(&par) {
                return Err(format!("mm_acc_par(4) != serial at {m}x{k}x{n}"));
            }

            let mut want = init.clone();
            gemm::naive::mm_nt_acc(&mut want, &a, &bt, m, k, n, alpha);
            for (label, f) in [
                ("tiled", gemm::tiled::mm_nt_acc as MmFn),
                ("simd", gemm::simd::mm_nt_acc as MmFn),
            ] {
                let mut got = init.clone();
                f(&mut got, &a, &bt, m, k, n, alpha);
                if bits(&want) != bits(&got) {
                    return Err(format!("mm_nt_acc {label} != naive at {m}x{k}x{n} alpha {alpha}"));
                }
            }
            let mut par = init.clone();
            gemm::mm_nt_acc_par(&mut par, &a, &bt, m, k, n, alpha, 3);
            if bits(&want) != bits(&par) {
                return Err(format!("mm_nt_acc_par(3) != serial at {m}x{k}x{n}"));
            }

            let mut want = init.clone();
            gemm::naive::mm_tn_acc(&mut want, &at, &b, k, m, n, alpha);
            for (label, f) in [
                ("tiled", gemm::tiled::mm_tn_acc as MmFn),
                ("simd", gemm::simd::mm_tn_acc as MmFn),
            ] {
                let mut got = init.clone();
                f(&mut got, &at, &b, k, m, n, alpha);
                if bits(&want) != bits(&got) {
                    return Err(format!("mm_tn_acc {label} != naive at {m}x{k}x{n} alpha {alpha}"));
                }
            }
            let mut par = init.clone();
            gemm::mm_tn_acc_par(&mut par, &at, &b, k, m, n, alpha, 4);
            if bits(&want) != bits(&par) {
                return Err(format!("mm_tn_acc_par(4) != serial at {m}x{k}x{n}"));
            }

            // Batched-fused multi-adapter driver vs the per-adapter naive
            // loop, with zero-padded ranks: each adapter's stored (k, m)
            // Aᵀ slice loses its trailing `pad` columns (rank padding),
            // so those output rows must be produced by the exact same
            // skipped-term sequence in both paths.
            let mut ab = buf(nb * k * m, 0.3);
            let bb = buf(nb * k * n, 0.0);
            for i in 0..nb {
                for kk in 0..k {
                    for c in m - pad..m {
                        ab[i * k * m + kk * m + c] = 0.0;
                    }
                }
            }
            let alphas: Vec<f32> = (0..nb).map(|i| [alpha, 1.0, -0.6, 0.0][i % 4]).collect();
            let binit = buf(nb * m * n, 0.0);
            let mut want = binit.clone();
            for i in 0..nb {
                gemm::naive::mm_tn_acc(
                    &mut want[i * m * n..(i + 1) * m * n],
                    &ab[i * k * m..(i + 1) * k * m],
                    &bb[i * k * n..(i + 1) * k * n],
                    k,
                    m,
                    n,
                    alphas[i],
                );
            }
            let prev = gemm::mode();
            for md in [gemm::Mode::Tiled, gemm::Mode::Simd, gemm::Mode::Naive] {
                gemm::set_mode(md);
                let mut got = binit.clone();
                gemm::batched::mm_tn_acc(&mut got, &ab, &bb, nb, k, m, n, Some(&alphas));
                let mut par = binit.clone();
                gemm::batched::mm_tn_acc_par(&mut par, &ab, &bb, nb, k, m, n, Some(&alphas), 3);
                let serial = bits(&got) == bits(&want);
                let parallel = bits(&par) == bits(&want);
                if !serial || !parallel {
                    gemm::set_mode(prev);
                    return Err(format!(
                        "batched {md:?} != per-adapter naive at nb={nb} {m}x{k}x{n} \
                         pad={pad} (serial ok: {serial}, par ok: {parallel})"
                    ));
                }
            }
            gemm::set_mode(prev);
            Ok(())
        },
    );
}

/// Rank masking in the padded state is exactly the identity on true ranks:
/// random (n, r_pad, ranks) always produce a 0/1 mask with row sums = ranks.
#[test]
fn rank_mask_row_sums_equal_ranks() {
    use plora::runtime::{ModelInfo, TrainState};
    let mi = ModelInfo {
        name: "t".into(),
        vocab: 64,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ff: 16,
        seq: 8,
        params: 0,
        weights: String::new(),
    };
    check(
        30,
        7,
        |rng| {
            let n = 1 + rng.usize_below(6);
            let r_pad = [4usize, 8, 16][rng.usize_below(3)];
            let ranks: Vec<usize> = (0..n).map(|_| 1 + rng.usize_below(r_pad)).collect();
            (n, ranks.iter().map(|&r| (r, r_pad)).collect::<Vec<(usize, usize)>>())
        },
        |(n, ranks_pairs)| {
            let r_pad = ranks_pairs.first().map(|&(_, p)| p).unwrap_or(4);
            if ranks_pairs.iter().any(|&(_, p)| p != r_pad) || ranks_pairs.len() != *n {
                return Ok(()); // shrunk into an inconsistent shape; skip
            }
            let ranks: Vec<usize> = ranks_pairs.iter().map(|&(r, _)| r.min(r_pad)).collect();
            let st = TrainState::init(&mi, *n, r_pad, 1);
            let mask = st.rank_mask(&ranks).map_err(|e| e.to_string())?;
            let data = mask.as_f32().map_err(|e| e.to_string())?;
            for (i, &r) in ranks.iter().enumerate() {
                let row = &data[i * r_pad..(i + 1) * r_pad];
                let sum: f32 = row.iter().sum();
                if sum != r as f32 {
                    return Err(format!("row {i} sum {sum} != rank {r}"));
                }
                if row.iter().any(|&x| x != 0.0 && x != 1.0) {
                    return Err("non 0/1 mask".into());
                }
            }
            Ok(())
        },
    );
}

/// Task generators: for random seeds and sequence lengths the samples are
/// in-vocab, target-shifted, and have a non-empty answer mask.
#[test]
fn task_samples_always_valid() {
    use plora::runtime::manifest::TokenLayout;
    use plora::train::tasks;
    let tl = TokenLayout { pad: 0, bos: 1, sep: 2, eos: 3, alpha0: 8 };
    check(
        60,
        97,
        |rng| {
            let seq = [16usize, 32, 64][rng.usize_below(3)];
            let task = rng.usize_below(4);
            (task, seq)
        },
        |&(task, seq)| {
            let name = tasks::TASKS[task.min(3)];
            let mut rng = Rng::new((task * 1000 + seq) as u64);
            for _ in 0..8 {
                let s = tasks::gen(name, &tl, &mut rng, seq.max(16), 256)
                    .map_err(|e| e.to_string())?;
                let seq = seq.max(16);
                if s.tokens.len() != seq || s.targets.len() != seq {
                    return Err("bad lengths".into());
                }
                if s.tokens.iter().chain(&s.targets).any(|&t| !(0..256).contains(&t)) {
                    return Err("token out of vocab".into());
                }
                for i in 0..seq - 1 {
                    if s.targets[i] != s.tokens[i + 1] {
                        return Err(format!("targets not shifted at {i}"));
                    }
                }
                if s.mask.iter().sum::<f32>() < 1.0 {
                    return Err("empty answer mask".into());
                }
            }
            Ok(())
        },
    );
}
