//! End-to-end tests of the default pure-Rust reference backend: the
//! runtime must come up with zero on-disk artifacts, execute the packed
//! kernel + train/eval artifact contract, drive real learning through the
//! train driver and engine, and stay bit-deterministic under `util::rng`.

use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{pool, LoraConfig, SearchSpace};
use plora::costmodel::TrainBudget;
use plora::engine::{CheckpointPool, Engine};
use plora::planner::JobPlanner;
use plora::runtime::{HostTensor, Runtime, TrainState};
use plora::sim::{SimOptions, Simulator};
use plora::train::{run_pack, run_pack_full, tasks, TrainOptions};

fn runtime() -> Arc<Runtime> {
    // Point at a directory with no artifacts: must synthesize everything.
    Arc::new(Runtime::load(&std::env::temp_dir().join("plora-no-artifacts")).unwrap())
}

fn cfg(id: usize, task: &str, rank: usize, bs: usize, lr: f64) -> LoraConfig {
    LoraConfig { id, lr, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
}

#[test]
fn runtime_comes_up_without_any_artifacts() {
    let rt = runtime();
    assert_eq!(rt.platform(), "ref-cpu");
    assert!(rt.manifest.models.contains_key("nano"));
    assert!(rt.manifest.models.contains_key("base"));
    assert!(!rt.manifest.artifacts.is_empty());
    assert!(rt.manifest.tasks.iter().any(|t| t == "parity"));
}

/// HostTensor → backend buffers → HostTensor round trip through a kernel
/// executable: shapes, dtypes and values all preserved/correct.
#[test]
fn kernel_fwd_round_trips_and_matches_reference_semantics() {
    let rt = runtime();
    for geom in ["attn", "mlp"] {
        let exe = rt.executable(&format!("kfwd_{geom}_n2")).unwrap();
        let info = &exe.info;
        let (n, m, d, r, k) = (
            2usize,
            info.meta_usize("m").unwrap(),
            info.meta_usize("d").unwrap(),
            info.meta_usize("r").unwrap(),
            info.meta_usize("k").unwrap(),
        );
        let x = HostTensor::f32(vec![n, m, d], vec![0.01; n * m * d]).unwrap();
        let a = HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r]).unwrap();
        let b = HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k]).unwrap();
        let alpha = HostTensor::f32(vec![n], vec![1.0, 0.5]).unwrap();
        let out = exe.run(&[x, a, b, alpha]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![n, m, k]);
        let y = out[0].as_f32().unwrap();
        // ref.py::ref_delta with constant tensors:
        // y_i = alpha_i * (d * 0.01 * 0.02) * (r * 0.03), every element.
        for (i, &al) in [1.0f32, 0.5].iter().enumerate() {
            let want = al * (d as f32 * 0.01 * 0.02) * (r as f32 * 0.03);
            let got = y[i * m * k];
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1e-3),
                "{geom} adapter {i}: got {got}, want {want}"
            );
        }
    }
}

/// With per-adapter lr = 0 the train step must leave the LoRA parameters
/// bit-identical, and its per-adapter loss must equal the eval artifact's
/// loss on the same batch (both are the same masked mean CE forward).
#[test]
fn zero_lr_train_step_is_pure_loss_evaluation() {
    let rt = runtime();
    let mi = rt.manifest.model("nano").unwrap().clone();
    let info = rt.manifest.train_bucket("nano", 1, 8, 1).unwrap().clone();
    let train_exe = rt.executable(&info.name).unwrap();
    let eval_exe = rt.executable(&rt.manifest.eval_for(&info).unwrap().name.clone()).unwrap();
    let base = rt.base_weights("nano").unwrap();

    let mut state = TrainState::init(&mi, 1, 8, 11);
    // Give B nonzero values so the loss actually depends on the adapter.
    for (name, t) in plora::runtime::LORA_ORDER.iter().zip(state.lora.iter_mut()) {
        if name.starts_with("b_") {
            for v in t.as_f32_mut().unwrap() {
                *v = 0.01;
            }
        }
    }
    let before: Vec<Vec<f32>> =
        state.lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();

    let mut rng = plora::util::rng::Rng::new(5);
    let pb = tasks::packed_batch(
        &["parity"],
        &rt.manifest.tokens,
        &mut rng,
        1,
        mi.seq,
        mi.vocab,
        None,
    )
    .unwrap();
    let (tokens, targets, mask) = (pb.tokens, pb.targets, pb.mask);
    let rmask = state.rank_mask(&[8]).unwrap();
    let per = state
        .step(&train_exe, &base, &tokens, &targets, &mask, &[1.0], &[0.0], &rmask)
        .unwrap();
    for (t, b) in state.lora.iter().zip(&before) {
        assert_eq!(t.as_f32().unwrap(), &b[..], "lr=0 must not move parameters");
    }
    assert_eq!(state.t, vec![1.0], "per-adapter step counter advances");

    let (loss, acc) = state.eval(&eval_exe, &base, &tokens, &targets, &mask, &[1.0]).unwrap();
    assert!((per[0] - loss[0]).abs() < 1e-6, "train per-loss {} vs eval loss {}", per[0], loss[0]);
    assert!((0.0..=1.0).contains(&acc[0]));
    assert!(per[0].is_finite() && per[0] > 0.0);
}

/// The reference backend actually learns: LoRA fine-tuning on the frozen
/// synthesized base must improve held-out loss on `parity` (the task the
/// random-base TinyLM learns most robustly — margin ≈ 0.4–1.0 nats).
#[test]
fn reference_backend_learns_parity() {
    let rt = runtime();
    let configs = vec![cfg(0, "parity", 8, 1, 2e-3)];
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 96, epochs: 1 },
        eval_batches: 2,
        seed: 1,
        log_every: 16,
    };
    let rep = run_pack(&rt, "nano", &configs, &opts).unwrap();
    assert_eq!(rep.steps, 96);
    let a = &rep.adapters[0];
    assert!(a.first_loss.is_finite() && a.final_loss.is_finite());
    assert!(
        a.final_loss < a.first_loss,
        "train loss must decrease: {} -> {}",
        a.first_loss,
        a.final_loss
    );
    assert!(
        a.eval_loss < a.base_loss,
        "held-out loss must improve over the frozen base: base {} vs eval {}",
        a.base_loss,
        a.eval_loss
    );
    assert!(!a.curve.is_empty());
    assert!(rep.rank_throughput() > 0.0);
}

/// Same seed ⇒ bit-identical trajectory; different seed ⇒ different.
#[test]
fn training_is_deterministic_per_seed() {
    let rt = runtime();
    let configs = vec![cfg(0, "modadd", 8, 1, 2e-3)];
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 6, epochs: 1 },
        eval_batches: 1,
        seed: 99,
        log_every: 1,
    };
    let a = run_pack(&rt, "nano", &configs, &opts).unwrap();
    let b = run_pack(&rt, "nano", &configs, &opts).unwrap();
    assert_eq!(a.adapters[0].final_loss, b.adapters[0].final_loss);
    assert_eq!(a.adapters[0].eval_loss, b.adapters[0].eval_loss);
    assert_eq!(a.adapters[0].curve, b.adapters[0].curve);
    let mut opts2 = opts.clone();
    opts2.seed = 100;
    let c = run_pack(&rt, "nano", &configs, &opts2).unwrap();
    assert_ne!(a.adapters[0].final_loss, c.adapters[0].final_loss);
}

/// Heterogeneous ranks inside a pack: the rank mask must zero the padded
/// rank columns of a lower-rank adapter after the first update.
#[test]
fn padded_rank_columns_are_masked_to_zero() {
    let rt = runtime();
    let configs = vec![cfg(0, "copy", 4, 1, 5e-3), cfg(1, "parity", 8, 1, 5e-3)];
    let opts = TrainOptions {
        budget: TrainBudget { dataset: 3, epochs: 1 },
        eval_batches: 1,
        seed: 7,
        log_every: 0,
    };
    let (rep, state) = run_pack_full(&rt, "nano", &configs, &opts).unwrap();
    assert_eq!(rep.bucket_r, 8);
    // a_* tensors: (L, n, din, r_pad), rank on the last axis.
    for (name, t) in plora::runtime::LORA_ORDER.iter().zip(&state.lora) {
        let shape = &t.shape;
        let (l, n, d2, d3) = (shape[0], shape[1], shape[2], shape[3]);
        let data = t.as_f32().unwrap();
        let is_a = name.starts_with("a_");
        for li in 0..l {
            for x2 in 0..d2 {
                for x3 in 0..d3 {
                    let rank_idx = if is_a { x3 } else { x2 };
                    if rank_idx >= 4 {
                        // adapter 0 has true rank 4
                        let idx = ((li * n) * d2 + x2) * d3 + x3;
                        assert_eq!(
                            data[idx], 0.0,
                            "{name}: padded rank col {rank_idx} not masked"
                        );
                    }
                }
            }
        }
    }
    // Adapter 1 (true rank 8) keeps nonzero values everywhere in A.
    let aq = &state.lora[4]; // a_q
    let (l, n, d2, d3) = (aq.shape[0], aq.shape[1], aq.shape[2], aq.shape[3]);
    assert_eq!((l, n), (rt.manifest.model("nano").unwrap().n_layers, 2));
    let data = aq.as_f32().unwrap();
    let slot1 = &data[d2 * d3..2 * d2 * d3]; // layer 0, adapter slot 1
    assert!(slot1.iter().any(|&v| v != 0.0));
}

/// Full pipeline smoke: plan on the live profile, execute on the engine
/// over the reference backend, checkpoint and reload adapters.
#[test]
fn engine_runs_planned_queue_on_reference_backend() {
    let rt = runtime();
    let mi = rt.manifest.model("nano").unwrap().clone();
    let geom = plora::config::geometry::tiny_geom(
        "nano", mi.n_layers, mi.d_model, mi.d_ff, mi.n_heads, mi.vocab, mi.seq,
    );
    let mut cm = plora::costmodel::CostModel::new(&geom, &pool::CPU_SIM);
    cm.charge_padding = true;
    cm.buckets = Some(rt.manifest.train_buckets("nano"));
    let configs: Vec<LoraConfig> = vec![
        cfg(0, "modadd", 8, 1, 2e-3),
        cfg(1, "parity", 8, 1, 2e-3),
        cfg(2, "copy", 8, 1, 2e-3),
    ];
    let mut planner = JobPlanner::new(cm, 2);
    planner.budget = TrainBudget { dataset: 4, epochs: 1 };
    let plan = planner.plan(&configs).unwrap();
    assert_eq!(plan.total_configs(), 3);

    let ckpt_dir = std::env::temp_dir().join("plora_refbackend_ckpts");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut engine = Engine::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2));
    engine.options.budget = planner.budget;
    engine.options.eval_batches = 1;
    engine.options.log_every = 0;
    engine.checkpoints = Some(CheckpointPool::new(&ckpt_dir, rt.clone()).unwrap());
    let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
    let report = engine.run("nano", &queue).unwrap();
    assert_eq!(report.total_adapters(), 3);
    assert!(report.makespan > 0.0);
    assert_eq!(engine.monitor.available(), 2, "all slots returned");

    let pool_ref = engine.checkpoints.as_ref().unwrap();
    assert_eq!(pool_ref.list("nano"), vec![0, 1, 2]);
    let t = pool_ref.load("nano", 1).unwrap();
    assert_eq!(t.len(), 14);
    let (_, aq) = t.iter().find(|(nm, _)| nm == "a_q").unwrap();
    assert_eq!(aq.shape, vec![mi.n_layers, mi.d_model, 8]);
}

/// Planner + simulator are fully deterministic under `util::rng`: the same
/// inputs reproduce the same schedule and the same (even noisy) timeline.
#[test]
fn simulator_and_planner_are_deterministic() {
    let cm = plora::costmodel::CostModel::new(
        plora::config::geometry::geom("qwen2.5-7b").unwrap(),
        &pool::A100_40G,
    );
    let grid = SearchSpace::default().grid("t");
    let plan_a = JobPlanner::new(cm.clone(), 8).plan(&grid).unwrap();
    let plan_b = JobPlanner::new(cm.clone(), 8).plan(&grid).unwrap();
    assert_eq!(plan_a.makespan, plan_b.makespan);
    assert_eq!(plan_a.jobs.len(), plan_b.jobs.len());
    let ids = |p: &plora::planner::Plan| -> Vec<Vec<usize>> {
        p.jobs.iter().map(|j| j.job.pack.configs.iter().map(|c| c.id).collect()).collect()
    };
    assert_eq!(ids(&plan_a), ids(&plan_b));

    let sim = Simulator { cm, budget: TrainBudget::default(), gpus: 8 };
    let queue: Vec<_> = plan_a.jobs.iter().map(|j| j.job.clone()).collect();
    let noisy = SimOptions { noise: 0.3, seed: 5, ..Default::default() };
    let r1 = sim.run_queue(&queue, &noisy);
    let r2 = sim.run_queue(&queue, &noisy);
    assert_eq!(r1.makespan, r2.makespan);
    assert_eq!(r1.jobs.len(), r2.jobs.len());
}
