//! End-to-end tests of the event-driven Session orchestration API on the
//! reference backend: dynamic admission, the event stream, elastic
//! re-bucketing at adapter-completion boundaries, mid-job adapter
//! admission, preemption + checkpoint-restore resume, checkpoint-on-finish,
//! and the per-adapter **bit-identity** between solo, packed, admitted
//! and preempted-resumed execution.
//!
//! CI runs this suite once per `Policy` via `PLORA_POLICY`
//! (`fifo`/`priority`/`preempt`) — per-adapter results must be
//! policy-invariant; only timelines change.

use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{pool, AdapterSpec, LoraConfig};
use plora::costmodel::{ExecMode, Pack, TrainBudget};
use plora::engine::CheckpointPool;
use plora::planner::PlannedJob;
use plora::runtime::Runtime;
use plora::session::{Event, JobSpec, Policy, Session};
use plora::train::{run_pack, TrainOptions};

fn runtime() -> Arc<Runtime> {
    // Point at a directory with no artifacts: synthesizes everything.
    Arc::new(Runtime::load(&std::env::temp_dir().join("plora-no-artifacts")).unwrap())
}

/// The policy CI parameterizes this suite over (default FIFO).
fn policy_from_env() -> Policy {
    std::env::var("PLORA_POLICY")
        .ok()
        .and_then(|s| Policy::parse(&s))
        .unwrap_or(Policy::Fifo)
}

fn opts(dataset: usize) -> TrainOptions {
    TrainOptions {
        budget: TrainBudget { dataset, epochs: 1 },
        eval_batches: 2,
        seed: 17,
        log_every: 0,
    }
}

fn spec(task: &str, rank: usize, batch: usize, lr: f64) -> AdapterSpec {
    AdapterSpec { lr, batch, rank, alpha_ratio: 1.0, task: task.into() }
}

fn close(a: f32, b: f32, what: &str) {
    assert!(
        (a - b).abs() <= 1e-5 * a.abs().max(1.0),
        "{what}: {a} vs {b} diverged beyond f32 tolerance"
    );
}

/// The acceptance path: a mixed queue through `submit`/`drain` observes a
/// `Rebucketed` event, and every adapter's results match the solo
/// `run_pack` path within f32 tolerance (per-adapter streams make the
/// trajectory independent of packing and bucket shape).
#[test]
fn session_mixed_queue_matches_solo_path() {
    let rt = runtime();
    let o = opts(16); // bs1 -> 16 steps, bs2 -> 8 steps
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2), "nano");
    session.options = o.clone();
    session.set_policy(policy_from_env());

    // Job 0: mixed batches — the bs2 adapter converges first, the bs1
    // survivor re-buckets (2, 8, 2) -> (1, 8, 1). Job 1: a solo adapter.
    let h0 = session
        .submit(JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("parity", 8, 2, 2e-3),
        ]))
        .unwrap();
    assert_eq!(h0.adapters, vec![0, 1], "session assigns adapter ids in order");
    let h1 = session.submit(JobSpec::new(vec![spec("copy", 8, 1, 2e-3)])).unwrap();
    assert_eq!((h1.job, h1.adapters.as_slice()), (1, &[2usize][..]));

    let report = session.drain().unwrap();
    assert_eq!(report.outcomes.len(), 2);
    assert_eq!(report.total_adapters(), 3);
    assert!(report.makespan > 0.0);
    assert!(report.rebuckets() >= 1, "mixed-batch job must re-bucket");
    let reb = report
        .events
        .iter()
        .find_map(|e| match e {
            Event::Rebucketed { job, from, to, survivors, .. } => {
                Some((*job, *from, *to, survivors.clone()))
            }
            _ => None,
        })
        .unwrap();
    assert_eq!(reb, (0, (2, 8, 2), (1, 8, 1), vec![0]));
    // Adapter-finished events cover all three adapters.
    let finished: Vec<usize> = report
        .events
        .iter()
        .filter_map(|e| match e {
            Event::AdapterFinished { adapter, .. } => Some(*adapter),
            _ => None,
        })
        .collect();
    assert_eq!(finished.len(), 3);

    // Per-adapter results equal the solo path.
    for (id, task, batch) in [(0usize, "modadd", 1usize), (1, "parity", 2), (2, "copy", 1)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let s = &solo.adapters[0];
        let p = report
            .outcomes
            .iter()
            .flat_map(|oc| &oc.report.adapters)
            .find(|a| a.config.id == id)
            .unwrap();
        close(s.base_loss, p.base_loss, &format!("{task} base_loss"));
        close(s.base_acc, p.base_acc, &format!("{task} base_acc"));
        close(s.first_loss, p.first_loss, &format!("{task} first_loss"));
        close(s.final_loss, p.final_loss, &format!("{task} final_loss"));
        close(s.eval_loss, p.eval_loss, &format!("{task} eval_loss"));
        close(s.eval_acc, p.eval_acc, &format!("{task} eval_acc"));
        assert_eq!(s.steps, p.steps);
    }
    assert_eq!(session.available(), 2, "all capacity returned");
}

/// The satellite acceptance: with one adapter converging early, a
/// `Rebucketed` event fires, the survivors train on a strictly smaller
/// bucket, the padded work shrinks, and the makespan does not regress
/// versus the pad-to-job-end run — with identical per-adapter results
/// (re-bucketing is a pure optimization).
#[test]
fn rebucketing_shrinks_work_and_makespan() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16 steps
    let job = PlannedJob {
        id: 0,
        pack: Pack::new(vec![
            spec("modadd", 8, 1, 2e-3).with_id(0),
            spec("copy", 8, 2, 2e-3).with_id(1),
        ]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    let run = |rebucket: bool| {
        let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
        s.options = o.clone();
        s.rebucket = rebucket;
        s.submit_planned(job.clone()).unwrap();
        s.drain().unwrap()
    };
    let with = run(true);
    let without = run(false);

    // The re-bucket happened, onto a strictly smaller bucket.
    assert_eq!(with.rebuckets(), 1);
    assert_eq!(without.rebuckets(), 0);
    let (from, to) = with
        .events
        .iter()
        .find_map(|e| match e {
            Event::Rebucketed { from, to, .. } => Some((*from, *to)),
            _ => None,
        })
        .unwrap();
    assert_eq!(from, (2, 8, 2));
    assert_eq!(to, (1, 8, 1));
    // Deterministic work proxy: padded rows strictly shrink.
    let rows = |r: &plora::session::SessionReport| r.outcomes[0].report.padded_rows;
    assert!(
        rows(&with) < rows(&without),
        "padded rows {} !< {}",
        rows(&with),
        rows(&without)
    );
    // 16 steps at (2,8,2)=4 rows + 16 at (1,8,1)=1 vs 32 at 4 rows.
    assert_eq!(rows(&with), 16 * 4 + 16);
    assert_eq!(rows(&without), 32 * 4);
    assert_eq!(with.outcomes[0].report.rebuckets, 1);
    // Wall clock: re-bucketing does ~2/3 of the padded work, so even with
    // generous slack for CI scheduling noise it must not regress. (The
    // padded-row assertions above are the deterministic work statement;
    // this guards the realized makespan.)
    assert!(
        with.makespan <= without.makespan * 1.25,
        "re-bucketed makespan {:.3}s regressed vs {:.3}s",
        with.makespan,
        without.makespan
    );
    // Re-bucketing is a pure optimization: identical per-adapter results.
    for (a, b) in with.outcomes[0]
        .report
        .adapters
        .iter()
        .zip(&without.outcomes[0].report.adapters)
    {
        close(a.final_loss, b.final_loss, "final_loss");
        close(a.eval_loss, b.eval_loss, "eval_loss");
        close(a.eval_acc, b.eval_acc, "eval_acc");
    }
}

/// Dynamic admission: jobs submitted while others run; checkpoints are
/// written per adapter as it finishes (including early finishers whose
/// slot a re-bucket then drops); sentinel ids are rejected at the door.
#[test]
fn dynamic_admission_checkpoints_and_id_hygiene() {
    let rt = runtime();
    let dir = std::env::temp_dir().join("plora_session_ckpts");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = opts(8);
    session.checkpoints = Some(CheckpointPool::new(&dir, rt.clone()).unwrap());
    let rx = session.subscribe();

    // Sentinel ids must never reach the checkpoint pool.
    let bad = PlannedJob {
        id: 7,
        pack: Pack::new(vec![LoraConfig {
            id: usize::MAX,
            lr: 1e-3,
            batch: 1,
            rank: 8,
            alpha_ratio: 1.0,
            task: "copy".into(),
        }]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    assert!(session.submit_planned(bad).is_err());

    // Admit a second job while the first is (potentially) running.
    session
        .submit(JobSpec::new(vec![spec("modadd", 8, 1, 2e-3), spec("copy", 8, 2, 2e-3)]))
        .unwrap();
    session.submit(JobSpec::new(vec![spec("parity", 8, 1, 2e-3)])).unwrap();
    let report = session.drain().unwrap();
    assert_eq!(report.outcomes.len(), 2);

    // Every adapter checkpointed — including the early finisher (id 1)
    // whose slot the re-bucket dropped mid-job.
    let ckpt = session.checkpoints.as_ref().unwrap();
    assert_eq!(ckpt.list("nano"), vec![0, 1, 2]);
    let t = ckpt.load("nano", 1).unwrap();
    assert_eq!(t.len(), 14);
    let meta = ckpt.load_meta("nano", 1).unwrap();
    assert_eq!(meta.field("task").unwrap().as_str().unwrap(), "copy");

    // The subscriber saw the same stream the log recorded, in order.
    let streamed: Vec<f64> = rx.try_iter().map(|e| e.at()).collect();
    assert_eq!(streamed.len(), report.events.len());
    // Per job: started before any of its adapters finish, finish last.
    for job in [0usize, 1] {
        let idx = |pred: &dyn Fn(&Event) -> bool| {
            report.events.iter().position(|e| pred(e)).unwrap()
        };
        let started = idx(&|e| matches!(e, Event::JobStarted { job: j, .. } if *j == job));
        let done = idx(&|e| matches!(e, Event::JobFinished { job: j, .. } if *j == job));
        assert!(started < done);
    }
}

/// Tentpole acceptance (a): **mid-job admission bit-identity**. A queued
/// single-adapter job joins a running pack at its first completion
/// boundary; the admitted adapter's whole trajectory — and everyone
/// else's — is bitwise identical to the solo `run_pack` path.
#[test]
fn mid_job_admission_is_bit_identical_to_solo() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16 steps
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = o.clone();
    session.set_policy(policy_from_env());
    session.set_elastic(true);

    // Job 0 holds the only device; job 1's copy adapter can only start by
    // joining job 0's pack when its parity adapter converges at step 16.
    session
        .submit(JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("parity", 8, 2, 2e-3),
        ]))
        .unwrap();
    session.submit(JobSpec::new(vec![spec("copy", 8, 2, 2e-3)])).unwrap();
    let report = session.drain().unwrap();

    assert_eq!(report.admissions(), 1, "copy must join mid-job");
    let admitted = report
        .events
        .iter()
        .find_map(|e| match e {
            Event::AdapterAdmitted { job, adapter, from_job, .. } => {
                Some((*job, *adapter, *from_job))
            }
            _ => None,
        })
        .unwrap();
    assert_eq!(admitted, (0, 2, 1), "adapter 2 moves from job 1 into job 0");
    // Job 1 was fully absorbed: one real outcome, three adapters in it,
    // and a zero-adapter JobFinished for the absorbed job.
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(report.total_adapters(), 3);
    assert!(report
        .events
        .iter()
        .any(|e| matches!(e, Event::JobFinished { job: 1, adapters: 0, .. })));

    // Bitwise identity for every adapter, including the admitted one.
    for (id, task, batch) in [(0usize, "modadd", 1usize), (1, "parity", 2), (2, "copy", 2)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let s = &solo.adapters[0];
        let p = report
            .outcomes
            .iter()
            .flat_map(|oc| &oc.report.adapters)
            .find(|a| a.config.id == id)
            .unwrap();
        assert_eq!(s.base_loss, p.base_loss, "{task}: base_loss not bit-identical");
        assert_eq!(s.base_acc, p.base_acc, "{task}: base_acc not bit-identical");
        assert_eq!(s.first_loss, p.first_loss, "{task}: first_loss not bit-identical");
        assert_eq!(s.final_loss, p.final_loss, "{task}: final_loss not bit-identical");
        assert_eq!(s.eval_loss, p.eval_loss, "{task}: eval_loss not bit-identical");
        assert_eq!(s.eval_acc, p.eval_acc, "{task}: eval_acc not bit-identical");
        assert_eq!(s.steps, p.steps);
    }
    assert_eq!(session.available(), 1);
}

/// Tentpole acceptance (b): **preempt-then-resume bit-identity through
/// the checkpoint pool**. A high-priority job evicts the running one
/// under `PreemptLowest`; the victim's members round-trip through
/// `save_resume`/`load_resume` on disk and resume bit-identically.
#[test]
fn preempt_and_resume_via_checkpoint_pool_is_bit_identical() {
    let rt = runtime();
    let o = opts(256); // long enough that the preemption lands mid-run
    let dir = std::env::temp_dir().join("plora_session_preempt_ckpts");
    let _ = std::fs::remove_dir_all(&dir);

    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = o.clone();
    session.set_policy(Policy::PreemptLowest);
    session.checkpoints = Some(CheckpointPool::new(&dir, rt.clone()).unwrap());
    let rx = session.subscribe();

    let low = PlannedJob {
        id: 0,
        pack: Pack::new(vec![spec("modadd", 8, 1, 2e-3).with_id(0)]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    session.submit_planned_at(low, 0).unwrap();
    // Wait for the low-priority job to actually hold the device, then
    // submit the high-priority one — the dispatcher must preempt.
    for ev in rx.iter() {
        if matches!(ev, Event::JobStarted { job: 0, .. }) {
            break;
        }
    }
    let high = PlannedJob {
        id: 1,
        pack: Pack::new(vec![spec("parity", 8, 1, 2e-3).with_id(1)]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    session.submit_planned_at(high, 5).unwrap();
    let report = session.drain().unwrap();

    assert_eq!(report.preemptions(), 1, "job 0 must be preempted exactly once");
    let preempted = report
        .events
        .iter()
        .find_map(|e| match e {
            Event::Preempted { job, adapters, .. } => Some((*job, adapters.clone())),
            _ => None,
        })
        .unwrap();
    assert_eq!(preempted, (0, vec![0]));
    // The resume checkpoint reached the pool on disk.
    assert!(dir.join("nano_cfg0_resume.bin").exists());
    assert!(dir.join("nano_cfg0_resume.json").exists());
    // The high-priority job finished before the victim's continuation.
    let finish_at = |job: usize| {
        report
            .events
            .iter()
            .find_map(|e| match e {
                Event::JobFinished { job: j, at, adapters, .. } if *j == job && *adapters > 0 => {
                    Some(*at)
                }
                _ => None,
            })
            .unwrap()
    };
    assert!(finish_at(1) < finish_at(0), "priority must be served first");

    // Bit-identity: the preempted-and-resumed adapter equals a solo run.
    let solo_cfg = LoraConfig {
        id: 0,
        lr: 2e-3,
        batch: 1,
        rank: 8,
        alpha_ratio: 1.0,
        task: "modadd".into(),
    };
    let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
    let s = &solo.adapters[0];
    let p = report
        .outcomes
        .iter()
        .flat_map(|oc| &oc.report.adapters)
        .find(|a| a.config.id == 0)
        .unwrap();
    assert_eq!(s.first_loss, p.first_loss, "first_loss not bit-identical after resume");
    assert_eq!(s.final_loss, p.final_loss, "final_loss not bit-identical after resume");
    assert_eq!(s.eval_loss, p.eval_loss, "eval_loss not bit-identical after resume");
    assert_eq!(s.eval_acc, p.eval_acc, "eval_acc not bit-identical after resume");
    assert_eq!(s.base_loss, p.base_loss, "base_loss not bit-identical after resume");
    assert_eq!(s.steps, p.steps);
    assert_eq!(session.available(), 1);
}

/// ASHA tuner acceptance: every rung **survivor's** full-budget result is
/// bitwise identical to its uninterrupted solo run — the rung stop at the
/// finish boundary plus the `MemberResume` continuation add nothing to
/// the trajectory — while demoted trials stop at their rung budget. Runs
/// under every `PLORA_POLICY` cell: rung decisions are policy-invariant.
#[test]
fn asha_rung_survivors_bit_identical_to_solo() {
    use plora::search::{Asha, SweepOptions, Tuner};

    let rt = runtime();
    // Two 4-trial task groups over an LR spread with one clear winner
    // each; dataset 32 with a 2-rung eta=2 ladder puts the cut at 16.
    let lrs = [2e-3, 1e-5, 2e-5, 5e-5];
    let configs: Vec<LoraConfig> = (0..8usize)
        .map(|i| {
            let task = if i < 4 { "modadd" } else { "copy" };
            spec(task, 8, 1, lrs[i % 4]).with_id(i)
        })
        .collect();
    let sweep = SweepOptions {
        budget: TrainBudget { dataset: 32, epochs: 1 },
        eval_batches: 2,
        seed: 17,
        gpus: 2,
        policy: policy_from_env(),
        elastic: false,
    };
    let tuner = Asha { eta: 2, rungs: 2, ckpt_dir: None };
    let out = tuner.run(&rt, "nano", &configs, &sweep, None).unwrap();
    assert_eq!(out.reports.len(), 8, "every trial reports at its last rung");
    assert_eq!(out.rungs.len(), 2);
    assert_eq!((out.rungs[0].trials, out.rungs[0].promoted), (8, 4));
    assert_eq!((out.rungs[1].trials, out.rungs[1].promoted), (4, 0));

    let o = TrainOptions {
        budget: sweep.budget,
        eval_batches: sweep.eval_batches,
        seed: sweep.seed,
        log_every: 0,
    };
    let full_steps = sweep.budget.steps(1);
    let survivors: Vec<_> = out.reports.iter().filter(|a| a.steps == full_steps).collect();
    assert_eq!(survivors.len(), 4, "eta=2 keeps half of each 4-trial group");
    for p in survivors {
        let solo = run_pack(&rt, "nano", &[p.config.clone()], &o).unwrap();
        let s = &solo.adapters[0];
        let what = format!("survivor {} ({})", p.config.id, p.config.task);
        assert_eq!(s.steps, p.steps, "{what}: steps");
        assert_eq!(s.first_loss, p.first_loss, "{what}: first_loss not bit-identical");
        assert_eq!(s.final_loss, p.final_loss, "{what}: final_loss not bit-identical");
        assert_eq!(s.eval_loss, p.eval_loss, "{what}: eval_loss not bit-identical");
        assert_eq!(s.eval_acc, p.eval_acc, "{what}: eval_acc not bit-identical");
        assert_eq!(s.param_hash, p.param_hash, "{what}: weights not bit-identical");
        assert_eq!(s.curve, p.curve, "{what}: loss curve not bit-identical");
    }
    for p in out.reports.iter().filter(|a| a.steps != full_steps) {
        assert_eq!(p.steps, 16, "demoted trial {} stops at the rung budget", p.config.id);
    }
}

/// Tentpole acceptance (c): **property test** — `retarget_bucket` never
/// picks a move whose modeled phase-time saving is at or below the switch
/// cost (when staying is feasible), always returns an admitting bucket,
/// and only forces a move when the current bucket cannot hold the
/// joiners.
#[test]
fn retarget_never_picks_move_below_switch_cost() {
    use plora::config::geometry::geom;
    use plora::costmodel::CostModel;
    use plora::planner::rebalance::{admits, retarget_bucket};
    use plora::util::rng::Rng;

    // cpu-sim is FLOP-bound: padded samples cost modeled time, so the
    // saving-vs-switch-cost tradeoff is exercised in both directions.
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &pool::CPU_SIM);
    let score = |b: (usize, usize, usize)| cm.bucket_step_time(b, 1, ExecMode::Packed);
    let mut rng = Rng::new(0xE1A5);
    let dims_n = [1usize, 2, 3, 4, 6, 8];
    let dims_r = [8usize, 16, 32, 64];
    let dims_bs = [1usize, 2, 4];
    let mut moves = 0usize;
    let mut stays = 0usize;
    for _ in 0..400 {
        // Random bucket grid.
        let mut grid: Vec<(usize, usize, usize)> = (0..rng.below(6) as usize + 2)
            .map(|_| {
                (
                    dims_n[rng.usize_below(dims_n.len())],
                    dims_r[rng.usize_below(dims_r.len())],
                    dims_bs[rng.usize_below(dims_bs.len())],
                )
            })
            .collect();
        grid.dedup();
        // Random survivor/joiner packs.
        let cfg = |rng: &mut Rng, id: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: dims_bs[rng.usize_below(dims_bs.len())],
            rank: dims_r[rng.usize_below(dims_r.len())],
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let ns = rng.usize_below(3) + 1;
        let nj = rng.usize_below(3);
        let survivors = Pack::new((0..ns).map(|i| cfg(&mut rng, i)).collect());
        let joiners = Pack::new((0..nj).map(|i| cfg(&mut rng, 100 + i)).collect());
        let current = grid[rng.usize_below(grid.len())];
        let switch_cost = [0.0, 1.0, 10.0, 1e9][rng.usize_below(4)];
        let phase_steps = rng.below(500) as usize;

        let mut combined = survivors.clone();
        combined.configs.extend(joiners.configs.iter().cloned());
        let got = retarget_bucket(
            &grid,
            &survivors,
            &joiners,
            current,
            &cm,
            switch_cost,
            phase_steps,
        );
        match got {
            Some(target) => {
                moves += 1;
                assert!(admits(target, &combined), "retarget returned a non-admitting bucket");
                assert_ne!(target, current, "a 'move' to the current bucket is a no-op");
                if admits(current, &combined) {
                    let saving = phase_steps as f64 * (score(current) - score(target));
                    assert!(
                        saving > switch_cost,
                        "move with saving {saving} <= switch cost {switch_cost}"
                    );
                }
            }
            None => {
                stays += 1;
                // If some admitting bucket exists and staying is feasible,
                // the *best* candidate must not have cleared the bar.
                if combined.n() > 0 && admits(current, &combined) {
                    let best = grid
                        .iter()
                        .copied()
                        .filter(|&b| b != current && admits(b, &combined))
                        .min_by(|&x, &y| score(x).total_cmp(&score(y)));
                    if let Some(b) = best {
                        let saving = phase_steps as f64 * (score(current) - score(b));
                        assert!(
                            saving <= switch_cost,
                            "stayed although the best move saves {saving} > {switch_cost}"
                        );
                    }
                }
            }
        }
    }
    assert!(moves > 10 && stays > 10, "property space degenerate: {moves} moves, {stays} stays");
}

/// Tentpole acceptance (d): **sharded execution bit-identity across
/// device counts**. The same workload — a mixed pack that re-buckets,
/// plus a queued single that joins mid-job (cross-`d` admission when the
/// host's width differs) — runs at d = 1, 2 and 4 on a pool of exactly d
/// devices; every adapter's full report must be bitwise identical across
/// all three, and identical to the solo `run_pack` path.
#[test]
fn sharded_execution_bit_identical_across_device_counts() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16
    let run_at = |d: usize| {
        let mut s =
            Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, d), "nano");
        s.options = o.clone();
        s.set_policy(policy_from_env());
        s.set_elastic(true);
        // Job 0 (d devices) holds the whole pool; job 1's copy adapter
        // can only start by joining job 0's pack at the parity boundary
        // — same-d admission at d=1, cross-d (a queued d=1 job entering
        // a d-wide host) otherwise.
        let mut j0 = JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("parity", 8, 2, 2e-3),
        ]);
        j0.d = d;
        s.submit(j0).unwrap();
        s.submit(JobSpec::new(vec![spec("copy", 8, 2, 2e-3)])).unwrap();
        s.drain().unwrap()
    };
    let pick = |r: &plora::session::SessionReport, id: usize| {
        r.outcomes
            .iter()
            .flat_map(|oc| oc.report.adapters.clone())
            .find(|a| a.config.id == id)
            .unwrap()
    };
    let base = run_at(1);
    assert_eq!(base.admissions(), 1);
    assert_eq!(base.total_adapters(), 3);
    // Solo ground truth (exact equality — the packed/sharded trajectory
    // is the solo trajectory).
    for (id, task, batch) in [(0usize, "modadd", 1usize), (1, "parity", 2), (2, "copy", 2)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let (s, p) = (&solo.adapters[0], pick(&base, id));
        assert_eq!(s.final_loss, p.final_loss, "{task}: d=1 final_loss vs solo");
        assert_eq!(s.eval_loss, p.eval_loss, "{task}: d=1 eval_loss vs solo");
    }
    for d in [2usize, 4] {
        let got = run_at(d);
        assert_eq!(got.admissions(), 1, "admission must fire at d={d}");
        assert_eq!(got.total_adapters(), 3);
        for id in 0..3usize {
            let (a, b) = (pick(&base, id), pick(&got, id));
            assert_eq!(a.first_loss, b.first_loss, "adapter {id} first_loss diverged at d={d}");
            assert_eq!(a.final_loss, b.final_loss, "adapter {id} final_loss diverged at d={d}");
            assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval_loss diverged at d={d}");
            assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval_acc diverged at d={d}");
            assert_eq!(a.base_loss, b.base_loss, "adapter {id} base_loss diverged at d={d}");
            assert_eq!(a.base_acc, b.base_acc, "adapter {id} base_acc diverged at d={d}");
            assert_eq!(a.curve, b.curve, "adapter {id} loss curve diverged at d={d}");
            assert_eq!(a.steps, b.steps);
        }
    }
}

/// Tentpole acceptance (e): **preempt-then-resume bit-identity across
/// device counts**. A sharded 2-adapter pack is evicted mid-run by a
/// higher-priority job and resumed; trajectories at d = 2 and 4 equal
/// the d = 1 run exactly (a resume is bit-exact at any boundary, so the
/// wall-clock-dependent preemption point cannot perturb results).
#[test]
fn preempt_resume_bit_identical_across_device_counts() {
    let rt = runtime();
    let o = opts(192); // long enough that the preemption lands mid-run
    let run_at = |d: usize| {
        let mut s =
            Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, d), "nano");
        s.options = o.clone();
        s.set_policy(Policy::PreemptLowest);
        let rx = s.subscribe();
        let low = PlannedJob {
            id: 0,
            pack: Pack::new(vec![
                spec("modadd", 8, 1, 2e-3).with_id(0),
                spec("copy", 8, 1, 2e-3).with_id(1),
            ]),
            d,
            s: 0,
            mode: ExecMode::Packed,
        };
        s.submit_planned_at(low, 0).unwrap();
        for ev in rx.iter() {
            if matches!(ev, Event::JobStarted { job: 0, .. }) {
                break;
            }
        }
        let high = PlannedJob {
            id: 1,
            pack: Pack::new(vec![spec("parity", 8, 1, 2e-3).with_id(2)]),
            d,
            s: 0,
            mode: ExecMode::Packed,
        };
        s.submit_planned_at(high, 5).unwrap();
        s.drain().unwrap()
    };
    let pick = |r: &plora::session::SessionReport, id: usize| {
        r.outcomes
            .iter()
            .flat_map(|oc| oc.report.adapters.clone())
            .find(|a| a.config.id == id)
            .unwrap()
    };
    let base = run_at(1);
    assert!(base.preemptions() >= 1, "the low-priority pack must be evicted");
    for d in [2usize, 4] {
        let got = run_at(d);
        assert!(got.preemptions() >= 1, "preemption must fire at d={d}");
        for id in 0..3usize {
            let (a, b) = (pick(&base, id), pick(&got, id));
            assert_eq!(a.final_loss, b.final_loss, "adapter {id} final_loss diverged at d={d}");
            assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval_loss diverged at d={d}");
            assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval_acc diverged at d={d}");
            assert_eq!(a.steps, b.steps);
        }
    }
}

/// Device-retarget property (split): a queued d=2 job **splits into two
/// d=1 hosts** — each of its adapters joins a different running d=1 pack
/// at that pack's completion boundary (cross-`d` admission) — with
/// results bitwise equal to the solo path, and the absorbed job retiring
/// with a zero-adapter `JobFinished`.
#[test]
fn queued_d2_job_splits_across_two_d1_hosts() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16
    let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2), "nano");
    s.options = o.clone();
    s.set_elastic(true);
    // Two d=1 hosts occupy both devices; each has a bs2 member leaving at
    // step 16 but room for only ONE joiner (nano's bs2 bucket tops out at
    // n=2) — so the queued d=2 job must split across them.
    for (id0, t0, t1) in [(0usize, "modadd", "parity"), (2, "modadd", "parity")] {
        let host = PlannedJob {
            id: id0 / 2,
            pack: Pack::new(vec![
                spec(t0, 8, 1, 2e-3).with_id(id0),
                spec(t1, 8, 2, 2e-3).with_id(id0 + 1),
            ]),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        };
        s.submit_planned(host).unwrap();
    }
    let queued = PlannedJob {
        id: 2,
        pack: Pack::new(vec![
            spec("copy", 8, 2, 2e-3).with_id(4),
            spec("needle", 8, 2, 2e-3).with_id(5),
        ]),
        d: 2,
        s: 0,
        mode: ExecMode::Packed,
    };
    s.submit_planned(queued).unwrap();
    let report = s.drain().unwrap();

    assert_eq!(report.admissions(), 2, "both adapters of the d=2 job must be absorbed");
    let hosts: std::collections::BTreeSet<usize> = report
        .events
        .iter()
        .filter_map(|e| match e {
            Event::AdapterAdmitted { job, from_job: 2, .. } => Some(*job),
            _ => None,
        })
        .collect();
    assert_eq!(hosts.len(), 2, "the d=2 job must split across two distinct d=1 hosts");
    assert!(report
        .events
        .iter()
        .any(|e| matches!(e, Event::JobFinished { job: 2, adapters: 0, .. })));
    assert_eq!(report.total_adapters(), 6);
    // Splitting never perturbs the math: every adapter equals its solo run.
    for (id, task, batch) in [(4usize, "copy", 2usize), (5, "needle", 2)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let sa = &solo.adapters[0];
        let p = report
            .outcomes
            .iter()
            .flat_map(|oc| &oc.report.adapters)
            .find(|a| a.config.id == id)
            .unwrap();
        assert_eq!(sa.final_loss, p.final_loss, "{task}: split final_loss diverged");
        assert_eq!(sa.eval_loss, p.eval_loss, "{task}: split eval_loss diverged");
        assert_eq!(sa.eval_acc, p.eval_acc, "{task}: split eval_acc diverged");
    }
    assert_eq!(s.available(), 2);
}

/// Device-retarget property (regrow): a running d=1 pack on the `tiny`
/// model grows onto the pool's free device at its first completion
/// boundary (`DeviceRetarget` event, shard set rebuilt at d=2) — and the
/// trajectory is bitwise identical to the run that never grew.
#[test]
fn running_pack_grows_onto_freed_devices_bit_identically() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs4 -> 8 (tiny has bs-4 buckets)
    let run = |gpus: usize, elastic: bool| {
        let mut s =
            Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, gpus), "tiny");
        s.options = o.clone();
        s.set_elastic(elastic);
        // Three adapters: the bs4 member leaves at step 8; the two bs1
        // survivors re-bucket to (2, 8, 1) — with a free device and a
        // modeled speedup, the survivors' phase grows to d=2.
        s.submit(JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("copy", 8, 1, 2e-3),
            spec("parity", 8, 4, 2e-3),
        ]))
        .unwrap();
        s.drain().unwrap()
    };
    let plain = run(1, false);
    let grown = run(2, true);
    assert!(
        grown.device_retargets() >= 1,
        "the surviving pack must grow onto the free device"
    );
    let (from, to) = grown
        .events
        .iter()
        .find_map(|e| match e {
            Event::DeviceRetarget { from, to, .. } => Some((*from, *to)),
            _ => None,
        })
        .unwrap();
    assert_eq!((from, to), (1, 2));
    assert!(grown.device_switch_cost >= 0.0);
    // Growth is execution-layout only: bitwise-identical results.
    for id in 0..3usize {
        let pick = |r: &plora::session::SessionReport| {
            r.outcomes
                .iter()
                .flat_map(|oc| oc.report.adapters.clone())
                .find(|a| a.config.id == id)
                .unwrap()
        };
        let (a, b) = (pick(&plain), pick(&grown));
        assert_eq!(a.first_loss, b.first_loss, "adapter {id} first_loss diverged on regrow");
        assert_eq!(a.final_loss, b.final_loss, "adapter {id} final_loss diverged on regrow");
        assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval_loss diverged on regrow");
        assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval_acc diverged on regrow");
    }
}

/// Stage-pipeline acceptance (a): **bitwise identity across pipeline
/// depths**. The same mixed queue — a pack that re-buckets plus a solo
/// job — runs at s = 1, 2 and 4 on one device; every adapter's full
/// report must be bitwise identical across all depths (nano has 2
/// layers, so s = 4 also pins the clamp to the layer stack), and the
/// s = 1 run equals the solo `run_pack` path exactly.
#[test]
fn stage_pipelined_execution_bit_identical_across_depths() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16
    let run_at = |st: usize| {
        let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
        s.options = o.clone();
        s.set_policy(policy_from_env());
        let mut j0 = JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("parity", 8, 2, 2e-3),
        ]);
        j0.s = st;
        s.submit(j0).unwrap();
        let mut j1 = JobSpec::new(vec![spec("copy", 8, 1, 2e-3)]);
        j1.s = st;
        s.submit(j1).unwrap();
        s.drain().unwrap()
    };
    let pick = |r: &plora::session::SessionReport, id: usize| {
        r.outcomes
            .iter()
            .flat_map(|oc| oc.report.adapters.clone())
            .find(|a| a.config.id == id)
            .unwrap()
    };
    let base = run_at(1);
    assert!(base.rebuckets() >= 1, "the mixed pack must re-bucket");
    // Solo ground truth at depth 1 (exact equality).
    for (id, task, batch) in [(0usize, "modadd", 1usize), (1, "parity", 2), (2, "copy", 1)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let (s, p) = (&solo.adapters[0], pick(&base, id));
        assert_eq!(s.final_loss, p.final_loss, "{task}: s=1 final_loss vs solo");
        assert_eq!(s.eval_loss, p.eval_loss, "{task}: s=1 eval_loss vs solo");
    }
    for st in [2usize, 4] {
        let got = run_at(st);
        assert_eq!(got.total_adapters(), 3);
        // nano has 2 layers: both requests run at effective depth 2.
        for oc in &got.outcomes {
            assert_eq!(oc.report.s, 2, "effective depth at requested s={st}");
        }
        for id in 0..3usize {
            let (a, b) = (pick(&base, id), pick(&got, id));
            assert_eq!(a.first_loss, b.first_loss, "adapter {id} first_loss diverged at s={st}");
            assert_eq!(a.final_loss, b.final_loss, "adapter {id} final_loss diverged at s={st}");
            assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval_loss diverged at s={st}");
            assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval_acc diverged at s={st}");
            assert_eq!(a.base_loss, b.base_loss, "adapter {id} base_loss diverged at s={st}");
            assert_eq!(a.curve, b.curve, "adapter {id} loss curve diverged at s={st}");
            assert_eq!(a.steps, b.steps);
        }
    }
}

/// Stage-pipeline acceptance (b): **uneven stage splits**. `tiny` has 4
/// layers; s = 3 forces a non-divisible split (2+1+1 layers per stage)
/// — trajectories must still equal the depth-1 run bitwise.
#[test]
fn uneven_stage_split_bit_identical_on_tiny() {
    let rt = runtime();
    let o = opts(16);
    let run_at = |st: usize| {
        let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "tiny");
        s.options = o.clone();
        let mut j = JobSpec::new(vec![spec("modadd", 8, 1, 2e-3), spec("copy", 8, 1, 2e-3)]);
        j.s = st;
        s.submit(j).unwrap();
        s.drain().unwrap()
    };
    let base = run_at(1);
    let got = run_at(3);
    assert_eq!(got.outcomes[0].report.s, 3, "tiny must run the full 3-stage split");
    for (a, b) in base.outcomes[0]
        .report
        .adapters
        .iter()
        .zip(&got.outcomes[0].report.adapters)
    {
        assert_eq!(a.final_loss, b.final_loss, "final_loss diverged on uneven split");
        assert_eq!(a.eval_loss, b.eval_loss, "eval_loss diverged on uneven split");
        assert_eq!(a.eval_acc, b.eval_acc, "eval_acc diverged on uneven split");
        assert_eq!(a.curve, b.curve, "loss curve diverged on uneven split");
    }
}

/// Stage-pipeline acceptance (c): **s × d composition**. A 2-adapter
/// pack at d = 2 with a 2-stage pipeline per shard equals the plain
/// d = 1, s = 1 run bitwise — the two parallelism axes compose without
/// touching the math.
#[test]
fn stage_and_device_axes_compose_bit_identically() {
    let rt = runtime();
    let o = opts(16);
    let run = |d: usize, st: usize| {
        let mut s =
            Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, d), "nano");
        s.options = o.clone();
        let job = PlannedJob {
            id: 0,
            pack: Pack::new(vec![
                spec("modadd", 8, 1, 2e-3).with_id(0),
                spec("parity", 8, 1, 2e-3).with_id(1),
            ]),
            d,
            s: st,
            mode: ExecMode::Packed,
        };
        s.submit_planned(job).unwrap();
        s.drain().unwrap()
    };
    let base = run(1, 1);
    let composed = run(2, 2);
    assert_eq!(composed.outcomes[0].report.d, 2);
    assert_eq!(composed.outcomes[0].report.s, 2);
    for (a, b) in base.outcomes[0]
        .report
        .adapters
        .iter()
        .zip(&composed.outcomes[0].report.adapters)
    {
        assert_eq!(a.final_loss, b.final_loss, "final_loss diverged under s x d");
        assert_eq!(a.eval_loss, b.eval_loss, "eval_loss diverged under s x d");
        assert_eq!(a.eval_acc, b.eval_acc, "eval_acc diverged under s x d");
        assert_eq!(a.curve, b.curve, "loss curve diverged under s x d");
    }
}

/// Stage-pipeline acceptance (d): **preempt-then-resume at depth**. The
/// pipelined pack is evicted mid-run by a higher-priority job and
/// resumed; trajectories at s = 2 equal the s = 1 run exactly (the
/// stage boundary handoff is deterministic, so the wall-clock-dependent
/// preemption point cannot perturb results).
#[test]
fn preempt_resume_bit_identical_across_stage_depths() {
    let rt = runtime();
    let o = opts(192); // long enough that the preemption lands mid-run
    let run_at = |st: usize| {
        let mut s =
            Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
        s.options = o.clone();
        s.set_policy(Policy::PreemptLowest);
        let rx = s.subscribe();
        let low = PlannedJob {
            id: 0,
            pack: Pack::new(vec![
                spec("modadd", 8, 1, 2e-3).with_id(0),
                spec("copy", 8, 1, 2e-3).with_id(1),
            ]),
            d: 1,
            s: st,
            mode: ExecMode::Packed,
        };
        s.submit_planned_at(low, 0).unwrap();
        for ev in rx.iter() {
            if matches!(ev, Event::JobStarted { job: 0, .. }) {
                break;
            }
        }
        let high = PlannedJob {
            id: 1,
            pack: Pack::new(vec![spec("parity", 8, 1, 2e-3).with_id(2)]),
            d: 1,
            s: st,
            mode: ExecMode::Packed,
        };
        s.submit_planned_at(high, 5).unwrap();
        s.drain().unwrap()
    };
    let pick = |r: &plora::session::SessionReport, id: usize| {
        r.outcomes
            .iter()
            .flat_map(|oc| oc.report.adapters.clone())
            .find(|a| a.config.id == id)
            .unwrap()
    };
    let base = run_at(1);
    assert!(base.preemptions() >= 1, "the low-priority pack must be evicted");
    let got = run_at(2);
    assert!(got.preemptions() >= 1, "preemption must fire at s=2");
    for id in 0..3usize {
        let (a, b) = (pick(&base, id), pick(&got, id));
        assert_eq!(a.final_loss, b.final_loss, "adapter {id} final_loss diverged at s=2");
        assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval_loss diverged at s=2");
        assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval_acc diverged at s=2");
        assert_eq!(a.steps, b.steps);
    }
}

/// The skewed-arrival acceptance scenario (mirrors `benches/session.rs`):
/// elastic admission + retargeting strictly beats the FIFO/no-rebucket
/// baseline — on the deterministic padded-row work proxy *and* on the
/// realized makespan.
#[test]
fn elastic_session_beats_fifo_baseline_on_skewed_queue() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16 steps
    // One device; a mixed pack holds it while two short bs2 singles queue
    // behind (each would burn a padded (2,8,2) bucket alone).
    let jobs = || {
        vec![
            PlannedJob {
                id: 0,
                pack: Pack::new(vec![
                    spec("modadd", 8, 1, 2e-3).with_id(0),
                    spec("parity", 8, 2, 2e-3).with_id(1),
                ]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            PlannedJob {
                id: 1,
                pack: Pack::new(vec![spec("copy", 8, 2, 2e-3).with_id(2)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            PlannedJob {
                id: 2,
                pack: Pack::new(vec![spec("needle", 8, 2, 2e-3).with_id(3)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
        ]
    };
    let run = |policy: Policy, elastic: bool, rebucket: bool| {
        let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
        s.options = o.clone();
        s.rebucket = rebucket;
        s.set_policy(policy);
        s.set_elastic(elastic);
        // Priorities descend in submit order: the mixed pack outranks the
        // singles, so they queue behind it (the admission opportunity).
        for (i, j) in jobs().into_iter().enumerate() {
            s.submit_planned_at(j, 10 - i as i32).unwrap();
        }
        s.drain().unwrap()
    };
    let fifo = run(Policy::Fifo, false, false);
    let elastic = run(Policy::Priority, true, true);

    // FIFO/no-rebucket burns full padded buckets: 32×4 + 16×4 + 16×4.
    assert_eq!(fifo.padded_rows(), 32 * 4 + 16 * 4 + 16 * 4);
    assert_eq!((fifo.admissions(), fifo.rebuckets()), (0, 0));
    // Elastic: one single joins job 0's freed slot at step 16 (the other
    // doesn't fit a bucket with 3 members at bs 2 and runs after).
    assert!(elastic.admissions() >= 1, "admission must fire on the skewed queue");
    assert!(
        elastic.padded_rows() < fifo.padded_rows(),
        "padded work must strictly shrink: {} vs {}",
        elastic.padded_rows(),
        fifo.padded_rows()
    );
    // The realized makespan is strictly below the baseline (the elastic
    // run does ~25% less padded work on the same device).
    assert!(
        elastic.makespan < fifo.makespan,
        "elastic makespan {:.3}s not below FIFO baseline {:.3}s",
        elastic.makespan,
        fifo.makespan
    );
    // Per-adapter results are unchanged by the orchestration (spot-check
    // the admitted adapter against the FIFO run).
    for id in 0..4usize {
        let pick = |r: &plora::session::SessionReport| {
            r.outcomes
                .iter()
                .flat_map(|oc| oc.report.adapters.clone())
                .find(|a| a.config.id == id)
                .unwrap()
        };
        let (a, b) = (pick(&fifo), pick(&elastic));
        assert_eq!(a.final_loss, b.final_loss, "adapter {id} final loss diverged");
        assert_eq!(a.eval_loss, b.eval_loss, "adapter {id} eval loss diverged");
        assert_eq!(a.eval_acc, b.eval_acc, "adapter {id} eval acc diverged");
    }
}
