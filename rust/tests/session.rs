//! End-to-end tests of the event-driven Session orchestration API on the
//! reference backend: dynamic admission, the event stream, preemptive
//! re-bucketing at adapter-completion boundaries, checkpoint-on-finish,
//! and the per-adapter equivalence between packed/re-bucketed execution
//! and the solo `run_pack` path.

use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{pool, AdapterSpec, LoraConfig};
use plora::costmodel::{ExecMode, Pack, TrainBudget};
use plora::engine::CheckpointPool;
use plora::planner::PlannedJob;
use plora::runtime::Runtime;
use plora::session::{Event, JobSpec, Session};
use plora::train::{run_pack, TrainOptions};

fn runtime() -> Arc<Runtime> {
    // Point at a directory with no artifacts: synthesizes everything.
    Arc::new(Runtime::load(&std::env::temp_dir().join("plora-no-artifacts")).unwrap())
}

fn opts(dataset: usize) -> TrainOptions {
    TrainOptions {
        budget: TrainBudget { dataset, epochs: 1 },
        eval_batches: 2,
        seed: 17,
        log_every: 0,
    }
}

fn spec(task: &str, rank: usize, batch: usize, lr: f64) -> AdapterSpec {
    AdapterSpec { lr, batch, rank, alpha_ratio: 1.0, task: task.into() }
}

fn close(a: f32, b: f32, what: &str) {
    assert!(
        (a - b).abs() <= 1e-5 * a.abs().max(1.0),
        "{what}: {a} vs {b} diverged beyond f32 tolerance"
    );
}

/// The acceptance path: a mixed queue through `submit`/`drain` observes a
/// `Rebucketed` event, and every adapter's results match the solo
/// `run_pack` path within f32 tolerance (per-adapter streams make the
/// trajectory independent of packing and bucket shape).
#[test]
fn session_mixed_queue_matches_solo_path() {
    let rt = runtime();
    let o = opts(16); // bs1 -> 16 steps, bs2 -> 8 steps
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2), "nano");
    session.options = o.clone();

    // Job 0: mixed batches — the bs2 adapter converges first, the bs1
    // survivor re-buckets (2, 8, 2) -> (1, 8, 1). Job 1: a solo adapter.
    let h0 = session
        .submit(JobSpec::new(vec![
            spec("modadd", 8, 1, 2e-3),
            spec("parity", 8, 2, 2e-3),
        ]))
        .unwrap();
    assert_eq!(h0.adapters, vec![0, 1], "session assigns adapter ids in order");
    let h1 = session.submit(JobSpec::new(vec![spec("copy", 8, 1, 2e-3)])).unwrap();
    assert_eq!((h1.job, h1.adapters.as_slice()), (1, &[2usize][..]));

    let report = session.drain().unwrap();
    assert_eq!(report.outcomes.len(), 2);
    assert_eq!(report.total_adapters(), 3);
    assert!(report.makespan > 0.0);
    assert!(report.rebuckets() >= 1, "mixed-batch job must re-bucket");
    let reb = report
        .events
        .iter()
        .find_map(|e| match e {
            Event::Rebucketed { job, from, to, survivors, .. } => {
                Some((*job, *from, *to, survivors.clone()))
            }
            _ => None,
        })
        .unwrap();
    assert_eq!(reb, (0, (2, 8, 2), (1, 8, 1), vec![0]));
    // Adapter-finished events cover all three adapters.
    let finished: Vec<usize> = report
        .events
        .iter()
        .filter_map(|e| match e {
            Event::AdapterFinished { adapter, .. } => Some(*adapter),
            _ => None,
        })
        .collect();
    assert_eq!(finished.len(), 3);

    // Per-adapter results equal the solo path.
    for (id, task, batch) in [(0usize, "modadd", 1usize), (1, "parity", 2), (2, "copy", 1)] {
        let solo_cfg =
            LoraConfig { id, lr: 2e-3, batch, rank: 8, alpha_ratio: 1.0, task: task.into() };
        let solo = run_pack(&rt, "nano", &[solo_cfg], &o).unwrap();
        let s = &solo.adapters[0];
        let p = report
            .outcomes
            .iter()
            .flat_map(|oc| &oc.report.adapters)
            .find(|a| a.config.id == id)
            .unwrap();
        close(s.base_loss, p.base_loss, &format!("{task} base_loss"));
        close(s.base_acc, p.base_acc, &format!("{task} base_acc"));
        close(s.first_loss, p.first_loss, &format!("{task} first_loss"));
        close(s.final_loss, p.final_loss, &format!("{task} final_loss"));
        close(s.eval_loss, p.eval_loss, &format!("{task} eval_loss"));
        close(s.eval_acc, p.eval_acc, &format!("{task} eval_acc"));
        assert_eq!(s.steps, p.steps);
    }
    assert_eq!(session.available(), 2, "all capacity returned");
}

/// The satellite acceptance: with one adapter converging early, a
/// `Rebucketed` event fires, the survivors train on a strictly smaller
/// bucket, the padded work shrinks, and the makespan does not regress
/// versus the pad-to-job-end run — with identical per-adapter results
/// (re-bucketing is a pure optimization).
#[test]
fn rebucketing_shrinks_work_and_makespan() {
    let rt = runtime();
    let o = opts(32); // bs1 -> 32 steps, bs2 -> 16 steps
    let job = PlannedJob {
        id: 0,
        pack: Pack::new(vec![
            spec("modadd", 8, 1, 2e-3).with_id(0),
            spec("copy", 8, 2, 2e-3).with_id(1),
        ]),
        d: 1,
        mode: ExecMode::Packed,
    };
    let run = |rebucket: bool| {
        let mut s = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
        s.options = o.clone();
        s.rebucket = rebucket;
        s.submit_planned(job.clone()).unwrap();
        s.drain().unwrap()
    };
    let with = run(true);
    let without = run(false);

    // The re-bucket happened, onto a strictly smaller bucket.
    assert_eq!(with.rebuckets(), 1);
    assert_eq!(without.rebuckets(), 0);
    let (from, to) = with
        .events
        .iter()
        .find_map(|e| match e {
            Event::Rebucketed { from, to, .. } => Some((*from, *to)),
            _ => None,
        })
        .unwrap();
    assert_eq!(from, (2, 8, 2));
    assert_eq!(to, (1, 8, 1));
    // Deterministic work proxy: padded rows strictly shrink.
    let rows = |r: &plora::session::SessionReport| r.outcomes[0].report.padded_rows;
    assert!(
        rows(&with) < rows(&without),
        "padded rows {} !< {}",
        rows(&with),
        rows(&without)
    );
    // 16 steps at (2,8,2)=4 rows + 16 at (1,8,1)=1 vs 32 at 4 rows.
    assert_eq!(rows(&with), 16 * 4 + 16);
    assert_eq!(rows(&without), 32 * 4);
    assert_eq!(with.outcomes[0].report.rebuckets, 1);
    // Wall clock: re-bucketing does ~2/3 of the padded work, so even with
    // generous slack for CI scheduling noise it must not regress. (The
    // padded-row assertions above are the deterministic work statement;
    // this guards the realized makespan.)
    assert!(
        with.makespan <= without.makespan * 1.25,
        "re-bucketed makespan {:.3}s regressed vs {:.3}s",
        with.makespan,
        without.makespan
    );
    // Re-bucketing is a pure optimization: identical per-adapter results.
    for (a, b) in with.outcomes[0]
        .report
        .adapters
        .iter()
        .zip(&without.outcomes[0].report.adapters)
    {
        close(a.final_loss, b.final_loss, "final_loss");
        close(a.eval_loss, b.eval_loss, "eval_loss");
        close(a.eval_acc, b.eval_acc, "eval_acc");
    }
}

/// Dynamic admission: jobs submitted while others run; checkpoints are
/// written per adapter as it finishes (including early finishers whose
/// slot a re-bucket then drops); sentinel ids are rejected at the door.
#[test]
fn dynamic_admission_checkpoints_and_id_hygiene() {
    let rt = runtime();
    let dir = std::env::temp_dir().join("plora_session_ckpts");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = opts(8);
    session.checkpoints = Some(CheckpointPool::new(&dir, rt.clone()).unwrap());
    let rx = session.subscribe();

    // Sentinel ids must never reach the checkpoint pool.
    let bad = PlannedJob {
        id: 7,
        pack: Pack::new(vec![LoraConfig {
            id: usize::MAX,
            lr: 1e-3,
            batch: 1,
            rank: 8,
            alpha_ratio: 1.0,
            task: "copy".into(),
        }]),
        d: 1,
        mode: ExecMode::Packed,
    };
    assert!(session.submit_planned(bad).is_err());

    // Admit a second job while the first is (potentially) running.
    session
        .submit(JobSpec::new(vec![spec("modadd", 8, 1, 2e-3), spec("copy", 8, 2, 2e-3)]))
        .unwrap();
    session.submit(JobSpec::new(vec![spec("parity", 8, 1, 2e-3)])).unwrap();
    let report = session.drain().unwrap();
    assert_eq!(report.outcomes.len(), 2);

    // Every adapter checkpointed — including the early finisher (id 1)
    // whose slot the re-bucket dropped mid-job.
    let ckpt = session.checkpoints.as_ref().unwrap();
    assert_eq!(ckpt.list("nano"), vec![0, 1, 2]);
    let t = ckpt.load("nano", 1).unwrap();
    assert_eq!(t.len(), 14);
    let meta = ckpt.load_meta("nano", 1).unwrap();
    assert_eq!(meta.field("task").unwrap().as_str().unwrap(), "copy");

    // The subscriber saw the same stream the log recorded, in order.
    let streamed: Vec<f64> = rx.try_iter().map(|e| e.at()).collect();
    assert_eq!(streamed.len(), report.events.len());
    // Per job: started before any of its adapters finish, finish last.
    for job in [0usize, 1] {
        let idx = |pred: &dyn Fn(&Event) -> bool| {
            report.events.iter().position(|e| pred(e)).unwrap()
        };
        let started = idx(&|e| matches!(e, Event::JobStarted { job: j, .. } if *j == job));
        let done = idx(&|e| matches!(e, Event::JobFinished { job: j, .. } if *j == job));
        assert!(started < done);
    }
}
