//! Record → replay determinism for `plora::trace`: every recorded session
//! must replay through a fresh real [`Session`] to a **bit-identical**
//! [`SessionDigest`] — per-adapter losses, accuracies, loss curves and the
//! FNV fingerprint of the final LoRA parameters all match exactly.
//!
//! The property is exercised across the full settings matrix
//! (`Policy` × job device count × elastic on/off), through an on-disk
//! save/load round trip each time, plus a preempt-then-resume recording
//! (the replay resumes in memory, without a checkpoint pool), an
//! ASHA-tuner recording (the replay re-runs the tuner from the rung-0
//! queue), and a timing-only replay through the simulator's cost model.

use std::sync::Arc;

use plora::cluster::ResourceMonitor;
use plora::config::{pool, AdapterSpec};
use plora::costmodel::{ExecMode, Pack, TrainBudget};
use plora::engine::CheckpointPool;
use plora::planner::PlannedJob;
use plora::runtime::Runtime;
use plora::session::{Event, Policy, Session};
use plora::trace::{replay, replay_timing, Trace, TraceRecorder};
use plora::train::TrainOptions;

fn runtime() -> Arc<Runtime> {
    // Point at a directory with no artifacts: synthesizes everything.
    Arc::new(Runtime::load(&std::env::temp_dir().join("plora-no-artifacts")).unwrap())
}

fn opts(dataset: usize) -> TrainOptions {
    // log_every=2 so the recorded digests carry non-trivial loss curves.
    TrainOptions {
        budget: TrainBudget { dataset, epochs: 1 },
        eval_batches: 1,
        seed: 17,
        log_every: 2,
    }
}

fn spec(task: &str, rank: usize, batch: usize, lr: f64) -> AdapterSpec {
    AdapterSpec { lr, batch, rank, alpha_ratio: 1.0, task: task.into() }
}

/// Run one small mixed-queue session under the given settings and record
/// it: job 0 packs two adapters (mixed batch sizes, so elastic runs hit a
/// re-bucket boundary) at priority 2, job 1 is a solo adapter at
/// priority 1.
fn record_cell(rt: &Arc<Runtime>, policy: Policy, d: usize, elastic: bool) -> Trace {
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 2), "nano");
    session.options = opts(8);
    session.set_policy(policy);
    session.set_elastic(elastic);
    let mut rec = TraceRecorder::for_session(&session);

    let jobs = [
        (
            PlannedJob {
                id: 0,
                pack: Pack::new(vec![
                    spec("modadd", 8, 1, 2e-3).with_id(0),
                    spec("parity", 8, 2, 2e-3).with_id(1),
                ]),
                d,
                s: 0,
                mode: ExecMode::Packed,
            },
            2,
        ),
        (
            PlannedJob {
                id: 1,
                pack: Pack::new(vec![spec("copy", 8, 1, 2e-3).with_id(2)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            1,
        ),
    ];
    for (job, prio) in jobs {
        rec.submit(&job, prio);
        session.submit_planned_at(job, prio).unwrap();
    }
    let report = session.drain().unwrap();
    rec.finish(&report)
}

/// The satellite property: **record → save → load → replay** round-trips
/// bit-identically for every `Policy` × device count × elastic cell. The
/// digest survives the on-disk JSON round trip exactly (bit patterns
/// travel as hex, not decimal floats), and the live replay reproduces it.
#[test]
fn record_replay_round_trips_across_policy_devices_elastic() {
    let rt = runtime();
    for policy in [Policy::Fifo, Policy::Priority, Policy::PreemptLowest] {
        for d in [1usize, 2] {
            for elastic in [false, true] {
                let cell = format!("{policy:?} d={d} elastic={elastic}");
                let trace = record_cell(&rt, policy, d, elastic);
                assert_eq!(trace.total_adapters(), 3, "{cell}: adapter count");
                assert_eq!(trace.gpus, 2, "{cell}: pool size");
                assert!(trace.makespan > 0.0, "{cell}: makespan");

                let path = std::env::temp_dir()
                    .join(format!("plora_trace_{policy:?}_d{d}_e{elastic}.json"));
                trace.save(&path).unwrap();
                let loaded = Trace::load(&path).unwrap();
                assert_eq!(loaded.digest, trace.digest, "{cell}: digest changed across save/load");
                assert_eq!(
                    loaded.digest.fingerprint(),
                    trace.digest.fingerprint(),
                    "{cell}: fingerprint changed across save/load"
                );
                assert_eq!(loaded.events.len(), trace.events.len(), "{cell}: event stream");

                let out = replay(rt.clone(), &loaded).unwrap();
                assert!(out.matches(), "{cell}: replay diverged from recording:\n{}", out.diff);
                // Replay proves the weights too, not just the metrics: a
                // zero param hash would mean the fingerprint is vacuous.
                for a in out.digest.adapters.values() {
                    assert_ne!(a.param_hash, 0, "{cell}: param hash must cover real weights");
                }
            }
        }
    }
}

/// A recording that contains a real preemption (high-priority job evicts
/// the running one through the checkpoint pool) still replays to the same
/// digest — the replay session has **no** checkpoint pool, so its resume
/// path (if its own race replays the eviction) round-trips in memory, and
/// either way the per-adapter trajectories are bit-identical.
#[test]
fn preempted_session_records_and_replays_bit_identically() {
    let rt = runtime();
    let dir = std::env::temp_dir().join("plora_trace_preempt_ckpts");
    let _ = std::fs::remove_dir_all(&dir);

    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = opts(256); // long enough that the preemption lands mid-run
    session.set_policy(Policy::PreemptLowest);
    session.checkpoints = Some(CheckpointPool::new(&dir, rt.clone()).unwrap());
    let rx = session.subscribe();
    let mut rec = TraceRecorder::for_session(&session);

    let low = PlannedJob {
        id: 0,
        pack: Pack::new(vec![spec("modadd", 8, 1, 2e-3).with_id(0)]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    rec.submit(&low, 0);
    session.submit_planned_at(low, 0).unwrap();
    // Wait for the low-priority job to actually hold the device, then
    // submit the high-priority one — the dispatcher must preempt.
    for ev in rx.iter() {
        if matches!(ev, Event::JobStarted { job: 0, .. }) {
            break;
        }
    }
    let high = PlannedJob {
        id: 1,
        pack: Pack::new(vec![spec("parity", 8, 1, 2e-3).with_id(1)]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    };
    rec.submit(&high, 5);
    session.submit_planned_at(high, 5).unwrap();
    let report = session.drain().unwrap();
    assert_eq!(report.preemptions(), 1, "job 0 must be preempted exactly once");

    let trace = rec.finish(&report);
    assert!(
        trace.events.iter().any(|e| matches!(e, Event::Preempted { .. })),
        "recorded timeline must contain the preemption"
    );
    assert_eq!(trace.jobs.len(), 2);
    assert_eq!(trace.jobs[1].priority, 5, "recorded priority travels with the job");

    let path = std::env::temp_dir().join("plora_trace_preempt.json");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    let out = replay(rt.clone(), &loaded).unwrap();
    assert!(out.matches(), "preempt-resume replay diverged:\n{}", out.diff);
}

/// An ASHA-driven sweep records through the same trace schema (the
/// rung-0 queue plus a tuner tag) and **replays bit-identically**: the
/// replay re-runs the tuner itself, whose rung decisions depend only on
/// already-finalized eval bit patterns ranked with a total order, so the
/// digest matches across the on-disk round trip even though the replay
/// races its own timeline.
#[test]
fn asha_recording_replays_bit_identically() {
    use plora::search::{Asha, SweepOptions, Tuner};
    use plora::trace::TunerSpec;

    let rt = runtime();
    let lrs = [2e-3, 1e-5, 2e-5, 5e-5];
    let configs: Vec<plora::config::LoraConfig> = (0..8usize)
        .map(|i| {
            let task = if i < 4 { "modadd" } else { "copy" };
            spec(task, 8, 1, lrs[i % 4]).with_id(i)
        })
        .collect();
    let sweep = SweepOptions {
        budget: TrainBudget { dataset: 32, epochs: 1 },
        eval_batches: 1,
        seed: 17,
        gpus: 2,
        policy: Policy::Fifo,
        elastic: false,
    };
    // The recorder holds the *full* final budget — rung budgets are the
    // tuner's business, reproduced from the tag at replay.
    let full = TrainOptions {
        budget: sweep.budget,
        eval_batches: sweep.eval_batches,
        seed: sweep.seed,
        log_every: 0,
    };
    let mut rec = TraceRecorder::new("nano", sweep.gpus, sweep.policy, sweep.elastic, true, &full);
    let tuner = Asha { eta: 2, rungs: 2, ckpt_dir: None };
    let out = tuner.run(&rt, "nano", &configs, &sweep, Some(&mut rec)).unwrap();
    let trace = rec.finish(&out.session);
    assert_eq!(trace.tuner, Some(TunerSpec { eta: 2, rungs: 2 }));
    assert_eq!(
        trace.jobs.iter().map(|j| j.configs.len()).sum::<usize>(),
        8,
        "the trace records the rung-0 queue only; continuations are the tuner's job"
    );
    assert!(
        trace.events.iter().any(|e| matches!(e, Event::RungDecision { .. })),
        "recorded timeline must contain the rung decisions"
    );
    assert!(
        trace.events.iter().any(|e| matches!(e, Event::TrialPromoted { .. })),
        "recorded timeline must contain the promotions"
    );

    let path = std::env::temp_dir().join("plora_trace_asha.json");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded.tuner, trace.tuner, "tuner tag changed across save/load");
    assert_eq!(loaded.digest, trace.digest, "digest changed across save/load");
    let res = replay(rt.clone(), &loaded).unwrap();
    assert!(res.matches(), "asha replay diverged from recording:\n{}", res.diff);
}

/// Stage depth travels with the trace: a recording whose job carries an
/// explicit pipeline depth round-trips `s` (and the `PLORA_STAGES`
/// settings snapshot) through save/load, and replays bit-identically —
/// depth moves the timeline, never the digest.
#[test]
fn pipelined_recording_round_trips_and_replays() {
    let rt = runtime();
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, 1), "nano");
    session.options = opts(8);
    let mut rec = TraceRecorder::for_session(&session);
    let job = PlannedJob {
        id: 0,
        pack: Pack::new(vec![
            spec("modadd", 8, 1, 2e-3).with_id(0),
            spec("copy", 8, 1, 2e-3).with_id(1),
        ]),
        d: 1,
        s: 2,
        mode: ExecMode::Packed,
    };
    rec.submit(&job, 0);
    session.submit_planned(job).unwrap();
    let report = session.drain().unwrap();
    let trace = rec.finish(&report);
    assert_eq!(trace.env.stages, 1, "settings snapshot records the PLORA_STAGES default");
    assert_eq!(trace.jobs[0].s, 2, "the explicit depth travels with the job");

    let path = std::env::temp_dir().join("plora_trace_pipelined.json");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded.jobs[0].s, 2, "depth changed across save/load");
    assert_eq!(loaded.env.stages, trace.env.stages, "env snapshot changed across save/load");
    assert_eq!(loaded.digest, trace.digest, "digest changed across save/load");
    let out = replay(rt.clone(), &loaded).unwrap();
    assert!(out.matches(), "pipelined replay diverged from recording:\n{}", out.diff);
}

/// Timing-only replay (`plora replay --sim`): the trace's queue and
/// settings rebuild a plausible timeline through the simulator's cost
/// model — same `Event` vocabulary, non-degenerate makespan/utilization,
/// and (non-elastic) the recorded job structure.
#[test]
fn timing_replay_rebuilds_a_plausible_timeline() {
    let rt = runtime();
    let trace = record_cell(&rt, Policy::Fifo, 1, false);
    let cm = plora::search::live_cost_model(&rt, "nano").unwrap();
    let res = replay_timing(&cm, &trace);
    assert!(res.makespan > 0.0, "modeled makespan must be positive");
    assert_eq!(res.jobs.len(), trace.jobs.len(), "non-elastic sim keeps the job structure");
    let started = res.log.iter().filter(|e| matches!(e, Event::JobStarted { .. })).count();
    let finished = res.log.iter().filter(|e| matches!(e, Event::JobFinished { .. })).count();
    assert!(started >= trace.jobs.len(), "every job must start in the modeled timeline");
    assert!(finished >= trace.jobs.len(), "every job must finish in the modeled timeline");
    let u = res.utilization();
    assert!(u > 0.0 && u <= 1.0 + 1e-9, "utilization {u} out of range");
}
