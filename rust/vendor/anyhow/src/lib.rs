//! In-tree, API-compatible subset of the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so the error
//! plumbing the codebase relies on is provided here: [`Error`] (a cheap
//! context-chain error), [`Result`], the [`anyhow!`]/[`bail!`] macros, and
//! the [`Context`] extension trait for `Result` and `Option`.
//!
//! Differences from the real crate: no backtraces and no downcasting — the
//! error is stored as a rendered message chain. `Display` prints the
//! outermost message; `{:#}` prints the whole chain joined by `": "`;
//! `Debug` prints the anyhow-style "Caused by" block.

use std::fmt;

/// `Result<T, anyhow::Error>` with an overridable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error. `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into an `Error`, capturing its source chain. This
// is the blanket impl real anyhow has; it is why `Error` itself must not
// implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (`Result`) or missing values (`Option`).
///
/// The `Result` impl is bound by `E: Into<Error>`, which covers both std
/// errors (via the `From` blanket above) and `Error` itself (via the
/// reflexive `From<T> for T`) with a single coherent impl.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert!(format!("{e:#}").contains("gone"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("base {}", 1));
        let e = r.with_context(|| "wrapped").unwrap_err();
        assert_eq!(format!("{e:#}"), "wrapped: base 1");
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let v = 5;
        let e = anyhow!("inline {v}");
        assert_eq!(e.to_string(), "inline 5");
        fn f() -> Result<()> {
            bail!("boom {}", 2)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 2");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root"));
    }
}
